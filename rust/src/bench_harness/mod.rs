//! Measurement harness for the E1-E8 benchmarks (criterion is unavailable
//! offline). Provides warmed-up, multi-sample timing with percentile
//! reporting, throughput runs over thread pools, an aligned table printer,
//! and CSV output under `target/bench_results/` for EXPERIMENTS.md.

use std::io::Write as _;
use std::time::{Duration, Instant};

/// One measured series: per-sample wall times for a fixed op count.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// ns per op for each sample.
    pub samples_ns: Vec<f64>,
    pub ops_per_sample: u64,
}

impl Measurement {
    pub fn mean_ns(&self) -> f64 {
        self.samples_ns.iter().sum::<f64>() / self.samples_ns.len() as f64
    }

    pub fn median_ns(&self) -> f64 {
        let mut v = self.samples_ns.clone();
        v.sort_by(f64::total_cmp);
        v[v.len() / 2]
    }

    pub fn min_ns(&self) -> f64 {
        self.samples_ns.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Ops/second at the median sample.
    pub fn throughput(&self) -> f64 {
        1e9 / self.median_ns()
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: Duration,
    pub samples: usize,
    pub min_sample_time: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            samples: 7,
            min_sample_time: Duration::from_millis(120),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            samples: 3,
            min_sample_time: Duration::from_millis(40),
        }
    }

    /// Measure `f` (which performs `ops` operations per call): warm up,
    /// then collect samples, auto-scaling iterations per sample so each
    /// sample runs at least `min_sample_time`.
    pub fn run<F: FnMut()>(&self, name: &str, ops: u64, mut f: F) -> Measurement {
        // Warmup + calibration.
        let warm_start = Instant::now();
        let mut calls = 0u64;
        while warm_start.elapsed() < self.warmup || calls == 0 {
            f();
            calls += 1;
        }
        let per_call = warm_start.elapsed().as_secs_f64() / calls as f64;
        let iters =
            ((self.min_sample_time.as_secs_f64() / per_call.max(1e-9)).ceil() as u64).max(1);

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed().as_nanos() as f64;
            samples_ns.push(dt / (iters * ops) as f64);
        }
        Measurement { name: name.to_string(), samples_ns, ops_per_sample: iters * ops }
    }

    /// Throughput of `threads` workers running `make_worker()` closures for
    /// `duration`; returns total ops/sec. Each worker closure performs one
    /// op per call and is polled until the deadline.
    pub fn run_threads<W, F>(&self, threads: usize, duration: Duration, make_worker: W) -> f64
    where
        W: Fn(usize) -> F,
        F: FnMut() -> u64 + Send,
    {
        std::thread::scope(|scope| {
            let deadline = Instant::now() + duration;
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let mut w = make_worker(t);
                    scope.spawn(move || {
                        let mut ops = 0u64;
                        while Instant::now() < deadline {
                            // Batch the clock check to keep overhead low.
                            for _ in 0..64 {
                                ops += w();
                            }
                        }
                        ops
                    })
                })
                .collect();
            let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
            total as f64 / duration.as_secs_f64()
        })
    }
}

/// Format `n` ops/sec human-readably.
pub fn fmt_rate(r: f64) -> String {
    if r >= 1e9 {
        format!("{:.2}G/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2}M/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2}K/s", r / 1e3)
    } else {
        format!("{r:.1}/s")
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2}µs", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Aligned plain-text table, printed to stdout and appended to a CSV file
/// under `target/bench_results/<bench>.csv` (for EXPERIMENTS.md).
pub struct Table {
    bench: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(bench: &str, headers: &[&str]) -> Self {
        Table {
            bench: bench.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    /// Print the table and write the CSV artifact. Returns the CSV path.
    pub fn finish(&self) -> std::path::PathBuf {
        let widths: Vec<usize> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| {
                self.rows.iter().map(|r| r[i].len()).chain([h.len()]).max().unwrap_or(0)
            })
            .collect();
        let line = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("\n== {} ==", self.bench);
        println!("{}", line(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for r in &self.rows {
            println!("{}", line(r));
        }

        let dir = std::path::Path::new("target/bench_results");
        std::fs::create_dir_all(dir).expect("create bench_results dir");
        let path = dir.join(format!("{}.csv", self.bench));
        let mut f = std::fs::File::create(&path).expect("create csv");
        writeln!(f, "{}", self.headers.join(",")).unwrap();
        for r in &self.rows {
            writeln!(f, "{}", r.join(",")).unwrap();
        }
        path
    }
}

/// One row of the snapshots-off/on hot-node read sweep
/// ([`read_topk_sweep`]).
pub struct ReadSweepRow {
    pub mode: &'static str,
    pub threads: usize,
    pub topk_per_s: f64,
    /// Snapshot rate over the list-walk rate at the same thread count
    /// (1.0 for the list-walk rows themselves).
    pub vs_list_walk: f64,
    /// Hardware counters over the measurement window (inherited into the
    /// worker threads); `available == false` where perf is unavailable.
    pub perf: crate::metrics::PerfSample,
    /// Per-query latency distribution from a short single-threaded pass
    /// after the throughput window (recorded into the same `Histogram`
    /// primitive the engine registry exposes) — the throughput loop stays
    /// clock-free so the headline rate is unperturbed.
    pub lat: crate::metrics::Snapshot,
}

/// The read-sweep fixture: one hot src node (0) with `fanout` Zipf(1.0)
/// edges, `train` batch-ingested observations, order repaired. Shared by
/// `mcprioq bench` and bench `e9_read_path` so the two sweeps measure the
/// same model shape and cannot silently diverge.
pub fn hot_node_chain(
    config: crate::chain::ChainConfig,
    fanout: usize,
    train: usize,
    seed: u64,
) -> std::sync::Arc<crate::chain::McPrioQ> {
    let chain = std::sync::Arc::new(crate::chain::McPrioQ::new(config));
    let zipf = crate::workload::Zipf::new(fanout.max(2), 1.0);
    let mut rng = crate::testutil::Rng64::new(seed);
    let mut batch = Vec::with_capacity(1_000);
    for _ in 0..train.div_ceil(1_000) {
        batch.clear();
        batch.extend((0..1_000).map(|_| (0u64, zipf.sample(&mut rng) as u64 + 1)));
        chain.observe_batch(&batch);
    }
    chain.repair();
    chain
}

/// Hot-node `infer_topk(0, k)` throughput for every thread count — list
/// walk first, then snapshots — with the on/off ratio filled in. The two
/// chains should come from [`hot_node_chain`] with snapshots disabled and
/// enabled respectively.
pub fn read_topk_sweep(
    bench: &Bench,
    window: Duration,
    threads: &[usize],
    k: usize,
    list_chain: &std::sync::Arc<crate::chain::McPrioQ>,
    snap_chain: &std::sync::Arc<crate::chain::McPrioQ>,
) -> Vec<ReadSweepRow> {
    let mut rows: Vec<ReadSweepRow> = Vec::with_capacity(2 * threads.len());
    for (mode, chain) in [("list-walk", list_chain), ("snapshot", snap_chain)] {
        for (i, &t) in threads.iter().enumerate() {
            // Fresh counters per row: `inherit` only covers threads spawned
            // after open(), and run_threads joins its workers before
            // returning, so the end snapshot sees their folded counts.
            let pc = crate::metrics::PerfCounters::open();
            let before = pc.snapshot();
            let rate = bench.run_threads(t, window, |_| {
                let chain = std::sync::Arc::clone(chain);
                let mut out = crate::chain::Recommendation::default();
                move || {
                    chain.infer_topk_into(0, k, &mut out);
                    1
                }
            });
            let perf = pc.snapshot().delta(&before);
            let vs_list_walk = if mode == "snapshot" {
                // The list-walk row at the same thread count is at index i.
                let base = rows[i].topk_per_s;
                if base > 0.0 {
                    rate / base
                } else {
                    0.0
                }
            } else {
                1.0
            };
            // Latency pass: single-threaded, per-query timing into the
            // registry's histogram primitive for the p50/p99 columns.
            let hist = crate::metrics::Histogram::new();
            let mut out = crate::chain::Recommendation::default();
            for _ in 0..5_000 {
                let t0 = Instant::now();
                chain.infer_topk_into(0, k, &mut out);
                hist.record(t0.elapsed().as_nanos() as u64);
            }
            rows.push(ReadSweepRow {
                mode,
                threads: t,
                topk_per_s: rate,
                vs_list_walk,
                perf,
                lat: hist.snapshot(),
            });
        }
    }
    rows
}

/// One row of the snapshot-layout threshold sweep
/// ([`threshold_layout_sweep`]): `infer_threshold` throughput with the
/// sorted prefix array (PR 2 binary search) vs the Eytzinger layout
/// (branchless descent + SIMD prefix copy), at one fanout.
pub struct ThresholdSweepRow {
    pub layout: &'static str,
    pub fanout: usize,
    pub thresholds_per_s: f64,
    /// Eytzinger rate over the sorted rate at the same fanout (1.0 for
    /// the sorted rows themselves) — the acceptance knob: ≥ 1.5 at
    /// fanout ≥ 64.
    pub vs_sorted: f64,
    /// Hardware counters over the measurement window; the layout's story
    /// should show up here as fewer branch misses per kiloinstruction.
    pub perf: crate::metrics::PerfSample,
}

/// Hot-node `infer_threshold(0, t)` throughput for each fanout, sorted
/// layout first, then Eytzinger, with the ratio filled in. Thresholds are
/// drawn uniformly from (0, 1) per call so the window covers both the
/// search-heavy regime (small `t`, few items copied) and the copy-heavy
/// one (`t` near 1, most of the prefix copied): the ratio reflects the
/// whole read path, not a cherry-picked prefix length.
pub fn threshold_layout_sweep(
    bench: &Bench,
    window: Duration,
    threads: usize,
    fanouts: &[usize],
    train: usize,
) -> Vec<ThresholdSweepRow> {
    use crate::chain::{ChainConfig, SnapLayout};

    let mut rows: Vec<ThresholdSweepRow> = Vec::with_capacity(2 * fanouts.len());
    for &fanout in fanouts {
        let mut sorted_rate = 0.0;
        for (layout, snap_layout) in
            [("sorted", SnapLayout::Sorted), ("eytzinger", SnapLayout::Eytzinger)]
        {
            let chain = hot_node_chain(
                ChainConfig { snap_layout, ..Default::default() },
                fanout,
                train,
                42,
            );
            let pc = crate::metrics::PerfCounters::open();
            let before = pc.snapshot();
            let rate = bench.run_threads(threads, window, |t| {
                let chain = std::sync::Arc::clone(&chain);
                let mut out = crate::chain::Recommendation::default();
                let mut rng = crate::testutil::Rng64::new(t as u64 + 1);
                move || {
                    chain.infer_threshold_into(0, rng.next_f64(), &mut out);
                    1
                }
            });
            let perf = pc.snapshot().delta(&before);
            let vs_sorted = if layout == "sorted" {
                sorted_rate = rate;
                1.0
            } else if sorted_rate > 0.0 {
                rate / sorted_rate
            } else {
                0.0
            };
            rows.push(ThresholdSweepRow {
                layout,
                fanout,
                thresholds_per_s: rate,
                vs_sorted,
                perf,
            });
        }
    }
    rows
}

/// One row of the durability ingest sweep ([`durability_sweep`]): queued
/// engine ingest with the WAL off ("memory") or on at each fsync policy.
pub struct DurabilityRow {
    pub mode: &'static str,
    pub updates_per_s: f64,
    /// Rate over the WAL-off rate (1.0 for the memory row itself) — the
    /// acceptance knob: `batch` must stay ≥ 0.85.
    pub vs_memory: f64,
}

/// Result of the recovery probe appended to the sweep: reopening the
/// `fsync = never` run's data dir and replaying its WAL from scratch.
pub struct RecoveryProbe {
    pub batches: u64,
    pub updates: u64,
    pub secs: f64,
    pub updates_per_s: f64,
}

/// The durability acceptance sweep (bench `e10_durability` and `mcprioq
/// bench --durability`): steady-state queued ingest through the full
/// pipeline (per-shard queues → shard-affine workers → WAL append →
/// `observe_batch`) with persistence off, then on at every fsync policy,
/// plus a cold recovery probe over the `never` run's surviving data.
/// Rates come from the engine's applied-update counter over the window,
/// so queued backlog is never credited. `root` must be a scratch
/// directory; each mode writes under `root/<mode>`.
pub fn durability_sweep(
    bench: &Bench,
    window: Duration,
    threads: usize,
    shards: usize,
    batch: usize,
    root: &std::path::Path,
) -> Result<(Vec<DurabilityRow>, RecoveryProbe), String> {
    use crate::config::{PersistSection, ServerConfig};
    use crate::coordinator::Engine;
    use crate::workload::{TransitionStream, ZipfChainStream};

    let threads = threads.max(1);
    let batch = batch.max(1);
    let make_config = |mode: &str| ServerConfig {
        shards: shards.max(1),
        queue_capacity: 65_536,
        persist: PersistSection {
            data_dir: if mode == "memory" {
                String::new()
            } else {
                root.join(mode).to_string_lossy().into_owned()
            },
            fsync: if mode == "memory" { "batch".into() } else { mode.to_string() },
            // Periodic checkpoints off: the sweep isolates WAL overhead.
            checkpoint_interval_ms: 0,
            ..PersistSection::default()
        },
        ..Default::default()
    };
    let drive = |engine: &std::sync::Arc<Engine>| -> f64 {
        let before = engine.stats().applied_updates;
        bench.run_threads(threads, window, |t| {
            let engine = std::sync::Arc::clone(engine);
            let mut stream = ZipfChainStream::new(10_000, 24, 1.1, t as u64 + 1);
            let mut buf = Vec::with_capacity(batch);
            move || {
                buf.clear();
                for _ in 0..batch {
                    buf.push(stream.next_transition());
                }
                engine.observe_batch(&buf);
                0
            }
        });
        let after = engine.stats().applied_updates;
        (after - before) as f64 / window.as_secs_f64()
    };

    let mut rows = Vec::new();
    let mut memory_rate = 0.0;
    for mode in ["memory", "never", "batch", "always"] {
        let config = make_config(mode);
        let engine = if mode == "memory" {
            Engine::new(&config, threads)
        } else {
            let (engine, _report) = crate::persist::open_engine(&config, threads)?;
            engine
        };
        let rate = drive(&engine);
        engine.quiesce();
        engine.shutdown();
        drop(engine);
        if mode == "memory" {
            memory_rate = rate;
        }
        let vs_memory = if memory_rate > 0.0 { rate / memory_rate } else { 0.0 };
        rows.push(DurabilityRow { mode, updates_per_s: rate, vs_memory });
    }

    // Cold recovery over the `never` run: no checkpoint was ever taken, so
    // this replays the entire WAL — the worst-case restart.
    let t0 = Instant::now();
    let (engine, report) = crate::persist::open_engine(&make_config("never"), 0)?;
    let secs = t0.elapsed().as_secs_f64();
    engine.shutdown();
    drop(engine);
    let probe = RecoveryProbe {
        batches: report.replayed_batches,
        updates: report.replayed_updates,
        secs,
        updates_per_s: if secs > 0.0 { report.replayed_updates as f64 / secs } else { 0.0 },
    };
    Ok((rows, probe))
}

/// Result of the checkpoint-cost probe ([`checkpoint_cost_probe`]): the
/// acceptance metric of incremental checkpoints — differential bytes must
/// scale with the nodes dirtied since the base, not the model size — plus
/// the decay-replay equality gate (recovery with a logged decay record
/// must equal a never-crashed reference; the CI bench smoke fails on a
/// miss).
pub struct CheckpointCostProbe {
    pub model_nodes: usize,
    /// Encoded size of the full base snapshot.
    pub full_bytes: u64,
    /// Nodes re-dirtied between the base and the differential.
    pub dirty_nodes: usize,
    /// Encoded size of the differential generation.
    pub delta_bytes: u64,
    /// `delta_bytes / full_bytes` — compare against `dirty_nodes /
    /// model_nodes` (equal up to per-node size variance).
    pub delta_vs_full: f64,
    /// Post-crash recovery (checkpoint chain + WAL tail with a decay
    /// record in it) equals the never-crashed reference export.
    pub decay_replay_ok: bool,
}

/// Build a durable engine with `nodes` src nodes, take a full checkpoint,
/// dirty `dirty_fraction` of the nodes, take a differential checkpoint,
/// then decay + trickle + crash + recover and compare against the live
/// reference. `root` must be a scratch directory.
pub fn checkpoint_cost_probe(
    shards: usize,
    nodes: usize,
    dirty_fraction: f64,
    root: &std::path::Path,
) -> Result<CheckpointCostProbe, String> {
    use crate::config::{PersistSection, ServerConfig};

    let nodes = nodes.max(16);
    let config = ServerConfig {
        shards: shards.max(1),
        queue_capacity: 65_536,
        persist: PersistSection {
            data_dir: root.join("ckpt-cost").to_string_lossy().into_owned(),
            fsync: "never".into(),
            // The probe drives checkpoints explicitly.
            checkpoint_interval_ms: 0,
            ..PersistSection::default()
        },
        ..Default::default()
    };
    let (engine, _) = crate::persist::open_engine(&config, 2)?;

    // Queued ingest (not the direct path): WAL appends happen on the
    // worker apply path, and the probe is about durable artifacts.
    let mut batch = Vec::with_capacity(1024);
    for src in 0..nodes as u64 {
        for k in 1..=4u64 {
            batch.push((src, src + k));
            if batch.len() == 1024 {
                engine.observe_batch(&batch);
                batch.clear();
            }
        }
    }
    engine.observe_batch(&batch);
    engine.quiesce();
    let full = engine.checkpoint()?;
    if full.kind != "full" {
        return Err(format!("first checkpoint was {}, expected full", full.kind));
    }

    let dirty_nodes = ((nodes as f64 * dirty_fraction).ceil() as usize).clamp(1, nodes);
    let touch: Vec<(u64, u64)> = (0..dirty_nodes as u64).map(|src| (src, src + 1)).collect();
    engine.observe_batch(&touch);
    engine.quiesce();
    let delta = engine.checkpoint()?;
    if delta.kind != "delta" {
        return Err(format!(
            "second checkpoint was {} ({} dirty of {}), expected delta",
            delta.kind, dirty_nodes, nodes
        ));
    }

    // Decay-replay gate: logged maintenance + a post-checkpoint tail must
    // recover byte-identically to the never-crashed state.
    engine.decay();
    engine.observe_batch(&touch);
    engine.quiesce();
    let reference = engine.export_quiesced();
    engine.shutdown();
    drop(engine);
    let (recovered, _) = crate::persist::open_engine(&config, 0)?;
    let decay_replay_ok = recovered.export() == reference;
    recovered.shutdown();

    Ok(CheckpointCostProbe {
        model_nodes: nodes,
        full_bytes: full.bytes,
        dirty_nodes,
        delta_bytes: delta.bytes,
        delta_vs_full: if full.bytes > 0 {
            delta.bytes as f64 / full.bytes as f64
        } else {
            0.0
        },
        decay_replay_ok,
    })
}

/// Result of the fault-recovery probe ([`fault_recovery_probe`]): the
/// graceful-degradation acceptance gate (DESIGN.md §8). An injected
/// ENOSPC window must degrade the engine (writes parked, reads served),
/// the heal loop must return it to healthy once space frees, and both the
/// healed live state *and* a crash-restart recovery over the healed WAL
/// must equal a never-faulted reference run byte-for-byte.
pub struct FaultRecoveryProbe {
    /// The engine left the healthy rung during the fault window.
    pub degraded: bool,
    /// It returned to healthy within the probe's deadline.
    pub healed: bool,
    /// Heal attempts the background task made (`wal_retry` gauge).
    pub wal_retries: u64,
    /// Healed live export and post-crash recovery both equal the
    /// never-faulted reference.
    pub recovery_equal: bool,
}

impl FaultRecoveryProbe {
    /// The single pass/fail the bench smoke gates on.
    pub fn ok(&self) -> bool {
        self.degraded && self.healed && self.recovery_equal
    }
}

/// Drive the same deterministic update stream into a never-faulted
/// reference engine and an engine whose disk "fills" mid-run (injected
/// ENOSPC that clears after a window), wait for the degradation ladder to
/// climb back to healthy, then compare the healed state and a cold
/// recovery against the reference. `root` must be a scratch directory.
pub fn fault_recovery_probe(
    shards: usize,
    root: &std::path::Path,
) -> Result<FaultRecoveryProbe, String> {
    use crate::config::{PersistSection, ServerConfig};
    use crate::coordinator::Health;

    let make = |dir: &str, plan: &str| ServerConfig {
        shards: shards.max(1),
        queue_capacity: 65_536,
        persist: PersistSection {
            data_dir: root.join(dir).to_string_lossy().into_owned(),
            fsync: "never".into(),
            checkpoint_interval_ms: 0,
            fault_plan: plan.to_string(),
            ..PersistSection::default()
        },
        ..Default::default()
    };
    let pairs: Vec<(u64, u64)> = (0..20_000u64).map(|i| (i % 511, i % 257 + 1)).collect();

    let (reference, _) = crate::persist::open_engine(&make("fault-ref", ""), 2)?;
    for chunk in pairs.chunks(256) {
        reference.observe_batch(chunk);
    }
    reference.quiesce();
    let expect = reference.export_quiesced();
    reference.shutdown();
    drop(reference);

    // The faulted run: ~64 KiB of WAL frames against a 16 KiB budget, so
    // ENOSPC fires mid-stream and clears 250ms later.
    let (engine, _) = crate::persist::open_engine(
        &make("fault-run", "seed=7;enospc_after=16384;enospc_window_ms=250"),
        2,
    )?;
    let mut degraded = false;
    for chunk in pairs.chunks(256) {
        engine.observe_batch(chunk);
        degraded |= engine.health() != Health::Healthy;
    }
    engine.quiesce(); // parked counts as settled: returns while degraded
    degraded |= engine.health() != Health::Healthy;
    let deadline = Instant::now() + Duration::from_secs(20);
    while engine.health() != Health::Healthy && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    let healed = engine.health() == Health::Healthy;
    let stats = engine.stats();
    // The heal loop having run at all also proves degradation happened —
    // robust even if every health() poll above raced past the window.
    degraded |= stats.wal_retry > 0;
    engine.quiesce();
    let live_equal = engine.export_quiesced() == expect;
    engine.shutdown();
    drop(engine);

    // Crash-restart equality over the healed WAL: the drained quarantine
    // re-appended every parked batch contiguously, so replay must rebuild
    // the reference state exactly.
    let (recovered, _) = crate::persist::open_engine(&make("fault-run", ""), 0)?;
    let recovery_equal = live_equal && recovered.export() == expect;
    recovered.shutdown();

    Ok(FaultRecoveryProbe {
        degraded,
        healed,
        wal_retries: stats.wal_retry,
        recovery_equal,
    })
}

/// Result of the replication bench ([`replication_sweep`]): leader wire
/// ingest rate, follower apply throughput, the steady-state record lag at
/// the moment the drive window ended, and how long the follower took to
/// drain to lag 0 afterwards.
pub struct ReplicationProbe {
    pub leader_updates_per_s: f64,
    pub follower_updates_per_s: f64,
    pub steady_lag_records: u64,
    pub catchup_secs: f64,
    /// True when leader and follower exports matched at quiescence (the
    /// bench double-checks the equality the tests prove).
    pub converged: bool,
}

/// The replication bench (`mcprioq bench --replication`): a durable
/// leader with a TCP front-end, a durable follower streaming its WAL
/// (full in-process `replicate` plane), and `threads` wire clients
/// driving `OBSERVEB` through `Client::connect_with_backoff`. Measures
/// follower apply throughput and steady-state lag — the two numbers that
/// say whether replica reads can actually keep up with leader ingest.
pub fn replication_sweep(
    bench: &Bench,
    window: Duration,
    threads: usize,
    shards: usize,
    batch: usize,
    root: &std::path::Path,
) -> Result<ReplicationProbe, String> {
    use crate::config::{PersistSection, ServerConfig};
    use crate::coordinator::{Client, Server};
    use crate::workload::{TransitionStream, ZipfChainStream};

    let threads = threads.max(1);
    let batch = batch.max(1);
    let make_config = |dir: &std::path::Path| ServerConfig {
        shards: shards.max(1),
        queue_capacity: 65_536,
        persist: PersistSection {
            data_dir: dir.to_string_lossy().into_owned(),
            fsync: "never".into(),
            checkpoint_interval_ms: 0,
            ..PersistSection::default()
        },
        ..Default::default()
    };

    let (leader, _) = crate::persist::open_engine(&make_config(&root.join("leader")), threads)?;
    let server = Server::bind(std::sync::Arc::clone(&leader), "127.0.0.1:0")
        .map_err(|e| format!("bind: {e}"))?;
    let addr = server.local_addr().to_string();
    let _server = server.spawn();
    let follower =
        crate::replicate::start_follower(make_config(&root.join("follower")), 1, &addr)?;

    let t0 = Instant::now();
    bench.run_threads(threads, window, |t| {
        let addr = addr.clone();
        let mut client = Client::connect_with_backoff(&addr, Duration::from_secs(5))
            .expect("bench client connects");
        let mut stream = ZipfChainStream::new(10_000, 24, 1.1, t as u64 + 1);
        let mut buf = Vec::with_capacity(batch);
        move || {
            buf.clear();
            for _ in 0..batch {
                buf.push(stream.next_transition());
            }
            let _ = client.observe_batch(&buf);
            0
        }
    });
    // Steady-state lag: how far behind is the follower at the instant the
    // offered load stops?
    let persist = leader.persist_state().expect("leader is durable");
    let steady_lag_records: u64 = persist
        .last_seqs()
        .iter()
        .zip(follower.state.applied_seqs())
        .map(|(h, a)| h.saturating_sub(a))
        .sum();

    // Catch-up: quiesce the leader, then time the drain to lag 0.
    leader.quiesce();
    let target = persist.last_seqs();
    let catch0 = Instant::now();
    let caught = follower.wait_caught_up(&target, Duration::from_secs(60));
    let catchup_secs = catch0.elapsed().as_secs_f64();
    let total_secs = t0.elapsed().as_secs_f64();
    let leader_updates = leader.stats().applied_updates;
    let follower_updates = follower.state.applied_updates();
    let converged = caught && {
        follower.engine.quiesce();
        leader.export_quiesced() == follower.engine.export_quiesced()
    };

    follower.stop();
    follower.engine.shutdown();
    leader.shutdown();
    Ok(ReplicationProbe {
        leader_updates_per_s: leader_updates as f64 / window.as_secs_f64(),
        follower_updates_per_s: follower_updates as f64 / total_secs.max(1e-9),
        steady_lag_records,
        catchup_secs,
        converged,
    })
}

/// Result of the telemetry-overhead gate ([`telemetry_overhead_probe`]):
/// wire read throughput with the per-query telemetry plane fully armed
/// (span tracing on + slow-query log at a 1 µs threshold, so every query
/// writes both rings — the worst case) vs fully disarmed. The CI bench
/// smoke fails when `overhead_frac` exceeds 3%.
pub struct TelemetryOverheadProbe {
    pub reads_per_s_off: f64,
    pub reads_per_s_on: f64,
    /// `(off - on) / off`; can go negative when run-to-run noise favors
    /// the armed windows.
    pub overhead_frac: f64,
}

/// Boot a server on a hot-node engine, drive `threads` wire clients of
/// `TOPK` through alternating disarmed/armed windows (best window per
/// mode, to damp scheduler noise), and report the armed cost. The
/// registry itself has no per-query toggle — counters and histograms are
/// always on and part of the baseline; what arming adds is exactly the
/// span/slow-log plane this probe prices.
pub fn telemetry_overhead_probe(
    bench: &Bench,
    window: Duration,
    threads: usize,
    fanout: usize,
) -> Result<TelemetryOverheadProbe, String> {
    use crate::config::ServerConfig;
    use crate::coordinator::{Client, Engine, Server};
    use crate::metrics::trace;

    let threads = threads.max(1);
    let config =
        ServerConfig { shards: 1, queue_capacity: 65_536, ..Default::default() };
    let engine = Engine::new(&config, 1);
    // Hot-node fixture, engine-side (same shape as hot_node_chain).
    let zipf = crate::workload::Zipf::new(fanout.max(2), 1.0);
    let mut rng = crate::testutil::Rng64::new(42);
    let mut batch = Vec::with_capacity(1_000);
    for _ in 0..50 {
        batch.clear();
        batch.extend((0..1_000).map(|_| (0u64, zipf.sample(&mut rng) as u64 + 1)));
        engine.observe_batch(&batch);
    }
    engine.quiesce();
    engine.repair();
    let server = Server::bind(std::sync::Arc::clone(&engine), "127.0.0.1:0")
        .map_err(|e| format!("bind: {e}"))?;
    let addr = server.local_addr().to_string();
    let _server = server.spawn();

    let drive = |armed: bool| -> f64 {
        if armed {
            trace::set_enabled(true);
            trace::set_slow_query_us(1);
        } else {
            trace::set_enabled(false);
            trace::set_slow_query_us(0);
        }
        bench.run_threads(threads, window, |_| {
            let mut client = Client::connect_with_backoff(&addr, Duration::from_secs(5))
                .expect("probe client connects");
            move || {
                let _ = client.topk(0, 10);
                1
            }
        })
    };
    let mut off = 0.0f64;
    let mut on = 0.0f64;
    for _ in 0..2 {
        off = off.max(drive(false));
        on = on.max(drive(true));
    }
    trace::set_enabled(false);
    trace::set_slow_query_us(0);
    engine.shutdown();
    let overhead_frac = if off > 0.0 { (off - on) / off } else { 0.0 };
    Ok(TelemetryOverheadProbe { reads_per_s_off: off, reads_per_s_on: on, overhead_frac })
}

/// Result of the audit-overhead gate ([`audit_overhead_probe`]): wire read
/// throughput with the correctness observatory armed at a 1 ms cadence —
/// far hotter than the production default — vs disarmed. The CI bench
/// smoke fails when `overhead_frac` exceeds 2% (DESIGN.md §10).
pub struct AuditOverheadProbe {
    pub reads_per_s_off: f64,
    pub reads_per_s_on: f64,
    /// `(off - on) / off`; can go negative when run-to-run noise favors
    /// the armed windows.
    pub overhead_frac: f64,
    /// Audit rounds completed across the armed windows, so the artifact
    /// records how much auditing the gate actually priced.
    pub audit_rounds: u64,
}

/// Boot a server on a hot-node engine (same fixture as
/// [`telemetry_overhead_probe`]), drive `threads` wire clients of `TOPK`
/// through alternating windows, and price the armed auditor: a sidecar
/// thread running error sampling plus the invariant watchdog every
/// millisecond during the armed windows only.
pub fn audit_overhead_probe(
    bench: &Bench,
    window: Duration,
    threads: usize,
    fanout: usize,
) -> Result<AuditOverheadProbe, String> {
    use crate::audit::{AuditConfig, Auditor};
    use crate::config::ServerConfig;
    use crate::coordinator::{Client, Engine, Server};
    use crate::sync::shim::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;

    let threads = threads.max(1);
    let config = ServerConfig { shards: 1, queue_capacity: 65_536, ..Default::default() };
    let engine = Engine::new(&config, 1);
    let zipf = crate::workload::Zipf::new(fanout.max(2), 1.0);
    let mut rng = crate::testutil::Rng64::new(42);
    let mut batch = Vec::with_capacity(1_000);
    for _ in 0..50 {
        batch.clear();
        batch.extend((0..1_000).map(|_| (0u64, zipf.sample(&mut rng) as u64 + 1)));
        engine.observe_batch(&batch);
    }
    engine.quiesce();
    engine.repair();
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0")
        .map_err(|e| format!("bind: {e}"))?;
    let addr = server.local_addr().to_string();
    let _server = server.spawn();

    let armed = Arc::new(AtomicBool::new(false));
    let stop = Arc::new(AtomicBool::new(false));
    let rounds = Arc::new(AtomicU64::new(0));
    let auditor_thread = {
        let engine = Arc::clone(&engine);
        let armed = Arc::clone(&armed);
        let stop = Arc::clone(&stop);
        let rounds = Arc::clone(&rounds);
        std::thread::spawn(move || {
            let mut auditor = Auditor::new(
                engine.telemetry(),
                AuditConfig {
                    interval_ms: 1,
                    sample_nodes: 32,
                    topk: 10,
                    check_nodes: 4096,
                    ..AuditConfig::default()
                },
            );
            while !stop.load(Ordering::SeqCst) {
                if armed.load(Ordering::SeqCst) {
                    engine.audit_round(&mut auditor, None);
                    rounds.fetch_add(1, Ordering::Relaxed);
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        })
    };

    let drive = |on: bool| -> f64 {
        armed.store(on, Ordering::SeqCst);
        bench.run_threads(threads, window, |_| {
            let mut client = Client::connect_with_backoff(&addr, Duration::from_secs(5))
                .expect("probe client connects");
            move || {
                let _ = client.topk(0, 10);
                1
            }
        })
    };
    let mut off = 0.0f64;
    let mut on = 0.0f64;
    for _ in 0..2 {
        off = off.max(drive(false));
        on = on.max(drive(true));
    }
    armed.store(false, Ordering::SeqCst);
    stop.store(true, Ordering::SeqCst);
    let _ = auditor_thread.join();
    engine.shutdown();
    let overhead_frac = if off > 0.0 { (off - on) / off } else { 0.0 };
    Ok(AuditOverheadProbe {
        reads_per_s_off: off,
        reads_per_s_on: on,
        overhead_frac,
        audit_rounds: rounds.load(Ordering::Relaxed),
    })
}

/// One point of the staleness-vs-error curve ([`staleness_error_curve`]):
/// the approximation error the audit probe measured against a snapshot
/// aged by roughly `target_staleness` edge-list mutations.
pub struct StalenessErrorPoint {
    /// Mutations applied after the snapshot was published.
    pub target_staleness: u64,
    /// Staleness the audit probe actually observed (swaps and splices age
    /// the snapshot beyond the applied increments).
    pub staleness: u64,
    /// Max absolute probability-mass error across the samples.
    pub mass_error: f64,
    pub rank_inversions: u64,
    pub displacement: u64,
    pub samples: usize,
}

/// Publish a fresh snapshot of one hot Zipf node, age it by a controlled
/// number of mutations, and read the audit probe — one row per target in
/// `targets`. This is the observability contract of DESIGN.md §10: the
/// `snap_staleness` serving bound is the x-axis knob that trades read
/// freshness for rebuild rate, and this curve prices that trade in
/// rank/mass error terms.
pub fn staleness_error_curve(targets: &[u64], fanout: usize) -> Vec<StalenessErrorPoint> {
    use crate::config::ServerConfig;
    use crate::coordinator::Engine;

    let mut config = ServerConfig { shards: 1, queue_capacity: 65_536, ..Default::default() };
    // Bound 0: every wire read republishes, so each curve point starts
    // from a perfectly fresh snapshot before its aging writes land.
    config.chain.snap_staleness = 0;
    let engine = Engine::new(&config, 1);
    let zipf = crate::workload::Zipf::new(fanout.max(2), 1.0);
    let mut rng = crate::testutil::Rng64::new(7);
    let mut batch = Vec::with_capacity(1_024);
    for _ in 0..50 {
        batch.clear();
        batch.extend((0..1_000).map(|_| (0u64, zipf.sample(&mut rng) as u64 + 1)));
        engine.observe_batch(&batch);
    }
    engine.quiesce();
    engine.repair();

    let mut out = Vec::with_capacity(targets.len());
    for &target in targets {
        // Fresh snapshot, then age it by ~target mutations (one increment
        // per observed pair, plus whatever swaps the reorder path adds).
        engine.infer_topk(0, 10);
        let mut left = target;
        while left > 0 {
            let n = left.min(1_024) as usize;
            batch.clear();
            batch.extend((0..n).map(|_| (0u64, zipf.sample(&mut rng) as u64 + 1)));
            engine.observe_batch(&batch);
            left -= n as u64;
        }
        engine.quiesce();
        let samples = engine.audit_error_samples(8, 10);
        let mut point = StalenessErrorPoint {
            target_staleness: target,
            staleness: 0,
            mass_error: 0.0,
            rank_inversions: 0,
            displacement: 0,
            samples: samples.len(),
        };
        for s in &samples {
            point.staleness = point.staleness.max(s.staleness);
            point.mass_error = point.mass_error.max(s.mass_error);
            point.rank_inversions += s.rank_inversions;
            point.displacement += s.displacement;
        }
        out.push(point);
    }
    engine.shutdown();
    out
}

/// One JSON value for [`JsonArtifact`] rows (serde is unavailable offline;
/// the bench artifacts only need numbers, strings, and booleans).
#[derive(Debug, Clone)]
pub enum JsonVal {
    Int(u64),
    Num(f64),
    Str(String),
    Bool(bool),
}

impl JsonVal {
    fn render(&self) -> String {
        match self {
            JsonVal::Int(v) => v.to_string(),
            // NaN/Inf are not JSON: degrade to null rather than emit an
            // unparseable artifact.
            JsonVal::Num(v) if !v.is_finite() => "null".to_string(),
            JsonVal::Num(v) => format!("{v}"),
            JsonVal::Str(s) => {
                let mut out = String::with_capacity(s.len() + 2);
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
                out
            }
            JsonVal::Bool(b) => b.to_string(),
        }
    }
}

/// Machine-readable benchmark artifact (`BENCH_read.json` /
/// `BENCH_update.json`): a named row set the CI bench-smoke step uploads,
/// so the perf trajectory is tracked across commits. Shape:
/// `{"bench": "...", "rows": [{"k": v, ...}, ...]}`.
pub struct JsonArtifact {
    bench: String,
    rows: Vec<String>,
}

impl JsonArtifact {
    pub fn new(bench: &str) -> Self {
        JsonArtifact { bench: bench.to_string(), rows: Vec::new() }
    }

    pub fn row(&mut self, fields: &[(&str, JsonVal)]) {
        let body: Vec<String> = fields
            .iter()
            .map(|(k, v)| format!("{}: {}", JsonVal::Str(k.to_string()).render(), v.render()))
            .collect();
        self.rows.push(format!("{{{}}}", body.join(", ")));
    }

    /// Serialize to the final JSON document.
    pub fn render(&self) -> String {
        format!(
            "{{\"bench\": {}, \"rows\": [{}]}}\n",
            JsonVal::Str(self.bench.clone()).render(),
            self.rows.join(", ")
        )
    }

    /// Write to `path`, creating parent directories. Returns the path.
    pub fn finish(&self, path: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.render())?;
        Ok(path.to_path_buf())
    }
}

/// `--quick` support for bench binaries: scale down when iterating locally.
pub fn bench_mode_from_env() -> Bench {
    if std::env::args().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench::default()
    }
}

/// Parse a comma-separated batch-size list (`"1,16,256"`): every element
/// must be a positive integer.
pub fn parse_batch_list(s: &str) -> Result<Vec<usize>, String> {
    let sizes: Vec<usize> = s
        .split(',')
        .map(|tok| tok.trim().parse::<usize>().map_err(|_| format!("bad batch size {tok:?}")))
        .collect::<Result<_, _>>()?;
    if sizes.is_empty() || sizes.contains(&0) {
        return Err(format!("batch sizes must be positive: {s:?}"));
    }
    Ok(sizes)
}

/// Batch sizes for the batch-first sweep benches: `--batches=1,16,256` on
/// the command line, else the `BENCH_BATCHES` env var, else `[1, 16, 256]`
/// (the acceptance sweep of the batch-first refactor).
pub fn batch_sizes_from_env() -> Vec<usize> {
    for arg in std::env::args() {
        if let Some(list) = arg.strip_prefix("--batches=") {
            match parse_batch_list(list) {
                Ok(sizes) => return sizes,
                Err(e) => eprintln!("ignoring --batches: {e}"),
            }
        }
    }
    if let Ok(list) = std::env::var("BENCH_BATCHES") {
        match parse_batch_list(&list) {
            Ok(sizes) => return sizes,
            Err(e) => eprintln!("ignoring BENCH_BATCHES: {e}"),
        }
    }
    vec![1, 16, 256]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_produces_sane_measurement() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            samples: 3,
            min_sample_time: Duration::from_millis(5),
        };
        let mut x = 0u64;
        let m = b.run("noop", 1, || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert_eq!(m.samples_ns.len(), 3);
        assert!(m.mean_ns() > 0.0);
        assert!(m.min_ns() <= m.mean_ns());
        assert!(m.throughput() > 0.0);
    }

    #[test]
    fn run_threads_counts_ops() {
        let b = Bench::quick();
        let rate = b.run_threads(2, Duration::from_millis(20), |_| {
            let mut x = 0u64;
            move || {
                x = x.wrapping_add(1);
                std::hint::black_box(x);
                1
            }
        });
        assert!(rate > 1000.0, "rate {rate}");
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_rate(2_500_000.0), "2.50M/s");
        assert_eq!(fmt_rate(3_200.0), "3.20K/s");
        assert_eq!(fmt_rate(1.5e9), "1.50G/s");
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(2_500.0), "2.50µs");
        assert_eq!(fmt_ns(3.1e6), "3.10ms");
    }

    #[test]
    fn table_writes_csv() {
        let mut t = Table::new("test_table", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let path = t.finish();
        let content = std::fs::read_to_string(path).unwrap();
        assert_eq!(content, "a,b\n1,2\n");
    }

    #[test]
    fn json_artifact_renders_and_writes() {
        let mut a = JsonArtifact::new("read");
        a.row(&[
            ("mode", JsonVal::Str("snap\"shot".into())),
            ("threads", JsonVal::Int(8)),
            ("rate", JsonVal::Num(1.5)),
            ("ok", JsonVal::Bool(true)),
            ("bad", JsonVal::Num(f64::NAN)),
        ]);
        a.row(&[("threads", JsonVal::Int(1))]);
        let s = a.render();
        assert_eq!(
            s,
            "{\"bench\": \"read\", \"rows\": [{\"mode\": \"snap\\\"shot\", \
             \"threads\": 8, \"rate\": 1.5, \"ok\": true, \"bad\": null}, \
             {\"threads\": 1}]}\n"
        );
        let path = std::env::temp_dir()
            .join(format!("mcprioq_json_{}", std::process::id()))
            .join("BENCH_test.json");
        let written = a.finish(&path).unwrap();
        assert_eq!(std::fs::read_to_string(written).unwrap(), s);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn batch_list_parsing() {
        assert_eq!(parse_batch_list("1,16,256").unwrap(), vec![1, 16, 256]);
        assert_eq!(parse_batch_list(" 8 , 64 ").unwrap(), vec![8, 64]);
        assert!(parse_batch_list("").is_err());
        assert!(parse_batch_list("1,0,4").is_err());
        assert!(parse_batch_list("1,x").is_err());
    }

    #[test]
    fn median_of_known_samples() {
        let m = Measurement {
            name: "x".into(),
            samples_ns: vec![3.0, 1.0, 2.0],
            ops_per_sample: 1,
        };
        assert_eq!(m.median_ns(), 2.0);
        assert_eq!(m.min_ns(), 1.0);
    }
}
