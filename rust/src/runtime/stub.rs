//! Offline stand-in for the PJRT runtime, compiled when the `xla` feature
//! is off (the default — the external `xla` crate cannot be vendored in the
//! offline build container).
//!
//! Public surface mirrors `loader.rs`/`dense.rs` exactly, so every caller
//! compiles unchanged; the only behavioural difference is that
//! [`XlaRuntime::new`] always fails, which every caller already treats as
//! "dense engine unavailable, skip it" (benches, examples, and the
//! differential test all branch on that `Result`).

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::manifest::Manifest;
use crate::baselines::MarkovModel;
use crate::chain::Recommendation;

/// An opaque handle to a compiled executable (never issued by the stub).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExeHandle(#[allow(dead_code)] usize);

/// A device buffer slot (never issued by the stub).
pub struct BufferBox {
    _confined: (),
}

impl BufferBox {
    /// An empty placeholder, mirroring the real API.
    pub fn poisoned() -> Self {
        BufferBox { _confined: () }
    }
}

/// Stub runtime: manifest parsing works, client creation does not.
pub struct XlaRuntime {
    manifest: Manifest,
}

impl XlaRuntime {
    /// Always fails: the PJRT client needs the `xla` feature. The manifest
    /// is still loaded first so the error message distinguishes "no
    /// artifacts" from "no runtime support".
    pub fn new(dir: &Path) -> Result<Self> {
        let _manifest = Manifest::load(dir)?;
        bail!("PJRT/XLA support not compiled in (rebuild with `--features xla`)")
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "unavailable (xla feature disabled)".to_string()
    }
}

/// Stub dense engine; construction always fails, methods are unreachable.
pub struct DenseXlaChain {
    #[allow(dead_code)]
    _rt: Arc<XlaRuntime>,
}

impl DenseXlaChain {
    pub fn new(_rt: Arc<XlaRuntime>, _nodes: usize) -> Result<Self> {
        bail!("dense engine requires the `xla` feature")
    }

    pub fn capacity(&self) -> usize {
        unreachable!("stub DenseXlaChain cannot be constructed")
    }

    pub fn usable_capacity(&self) -> usize {
        unreachable!("stub DenseXlaChain cannot be constructed")
    }

    pub fn batch_size(&self) -> usize {
        unreachable!("stub DenseXlaChain cannot be constructed")
    }

    pub fn k(&self) -> usize {
        unreachable!("stub DenseXlaChain cannot be constructed")
    }

    pub fn resident_bytes(&self) -> usize {
        unreachable!("stub DenseXlaChain cannot be constructed")
    }

    pub fn try_observe(&self, _src: u64, _dst: u64) -> Result<()> {
        unreachable!("stub DenseXlaChain cannot be constructed")
    }
}

impl MarkovModel for DenseXlaChain {
    fn name(&self) -> &'static str {
        "dense-xla-stub"
    }

    fn observe(&self, _src: u64, _dst: u64) {
        unreachable!("stub DenseXlaChain cannot be constructed")
    }

    fn infer_threshold(&self, _src: u64, _threshold: f64) -> Recommendation {
        unreachable!("stub DenseXlaChain cannot be constructed")
    }

    fn infer_topk(&self, _src: u64, _k: usize) -> Recommendation {
        unreachable!("stub DenseXlaChain cannot be constructed")
    }

    fn decay(&self) -> (u64, usize) {
        unreachable!("stub DenseXlaChain cannot be constructed")
    }

    fn edge_count(&self) -> usize {
        unreachable!("stub DenseXlaChain cannot be constructed")
    }
}
