//! Runtime tests: manifest parsing (always) and end-to-end PJRT execution
//! (`xla` feature builds only, and when `artifacts/` exists — `make
//! artifacts` builds it; tests that need it are skipped gracefully
//! otherwise so `cargo test` works standalone).

use super::*;
use std::path::Path;

#[test]
fn manifest_parses_and_indexes() {
    let text = "\
infer 64 8 8 dense_infer_n64_b8_k8.hlo.txt
update 64 8 0 dense_update_n64_b8.hlo.txt
decay 64 0 0 dense_decay_n64.hlo.txt
infer 256 8 16 dense_infer_n256_b8_k16.hlo.txt
update 256 8 0 dense_update_n256_b8.hlo.txt
decay 256 0 0 dense_decay_n256.hlo.txt
";
    let m = Manifest::parse(Path::new("/nonexistent"), text).unwrap();
    assert_eq!(m.entries.len(), 6);
    assert_eq!(m.capacities(), vec![64, 256]);
    assert_eq!(m.variant_for(10), Some(64));
    assert_eq!(m.variant_for(64), Some(64));
    assert_eq!(m.variant_for(65), Some(256));
    assert_eq!(m.variant_for(9999), None);
    let e = m.entry(ArtifactKind::Infer, 256).unwrap();
    assert_eq!(e.k, 16);
    assert_eq!(e.b, 8);
}

#[test]
fn manifest_rejects_garbage() {
    assert!(Manifest::parse(Path::new("/x"), "").is_err());
    assert!(Manifest::parse(Path::new("/x"), "infer 64 8\n").is_err());
    assert!(Manifest::parse(Path::new("/x"), "bogus 64 8 8 f.hlo.txt\n").is_err());
    assert!(Manifest::parse(Path::new("/x"), "infer x 8 8 f.hlo.txt\n").is_err());
}

#[cfg(feature = "xla")]
mod pjrt {
    use super::*;
    use crate::baselines::MarkovModel;
    use std::sync::Arc;

    fn runtime() -> Option<Arc<XlaRuntime>> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping PJRT test: no artifacts at {dir:?} (run `make artifacts`)");
            return None;
        }
        Some(Arc::new(XlaRuntime::new(&dir).expect("runtime")))
    }

#[test]
fn pjrt_client_comes_up() {
    let Some(rt) = runtime() else { return };
    assert!(rt.platform().to_lowercase().contains("cpu"), "{}", rt.platform());
    assert!(!rt.manifest().capacities().is_empty());
}

#[test]
fn executables_compile_and_cache() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest().capacities()[0];
    let a = rt.executable(ArtifactKind::Infer, n).unwrap();
    let b = rt.executable(ArtifactKind::Infer, n).unwrap();
    assert_eq!(a, b, "executable cache miss on second fetch");
    assert!(rt.executable(ArtifactKind::Infer, 7777).is_err());
}

#[test]
fn dense_observe_infer_roundtrip() {
    let Some(rt) = runtime() else { return };
    let dense = DenseXlaChain::new(rt, 32).unwrap();
    assert_eq!(dense.capacity(), 64);
    // 1 -> 5 x3, 1 -> 9 x2, 1 -> 3 x1.
    for _ in 0..3 {
        dense.observe(1, 5);
    }
    for _ in 0..2 {
        dense.observe(1, 9);
    }
    dense.observe(1, 3);
    let r = dense.infer_topk(1, 3);
    assert_eq!(r.total, 6);
    assert_eq!(r.items.len(), 3);
    assert_eq!(r.items[0].0, 5);
    assert!((r.items[0].1 - 0.5).abs() < 1e-6);
    assert_eq!(r.items[1].0, 9);
    assert_eq!(r.items[2].0, 3);
    assert!((r.cumulative - 1.0).abs() < 1e-6);

    let r = dense.infer_threshold(1, 0.5);
    assert_eq!(r.items.len(), 1);
    let r = dense.infer_threshold(1, 0.75);
    assert_eq!(r.items.len(), 2);
}

#[test]
fn dense_unknown_and_out_of_range() {
    let Some(rt) = runtime() else { return };
    let dense = DenseXlaChain::new(rt, 16).unwrap();
    let r = dense.infer_topk(2, 4);
    assert!(r.items.is_empty());
    assert_eq!(r.total, 0);
    // Out of compiled capacity: error, not panic.
    assert!(dense.try_observe(9999, 1).is_err());
    assert!(dense.try_observe(1, dense.usable_capacity() as u64).is_err());
    let r = dense.infer_topk(9999, 4);
    assert!(r.items.is_empty());
}

#[test]
fn dense_decay_halves_and_prunes() {
    let Some(rt) = runtime() else { return };
    let dense = DenseXlaChain::new(rt, 32).unwrap();
    for _ in 0..4 {
        dense.observe(2, 7);
    }
    dense.observe(2, 8); // count 1: dies on first decay
    assert_eq!(dense.edge_count(), 2);
    let (total, pruned) = dense.decay();
    assert_eq!(total, 2); // floor(4/2) + floor(1/2)
    assert_eq!(pruned, 1);
    assert_eq!(dense.edge_count(), 1);
    let r = dense.infer_topk(2, 4);
    assert_eq!(r.items.len(), 1);
    assert_eq!(r.items[0].0, 7);
}

/// Differential vs MCPrioQ: identical deterministic workload, identical
/// answers (the three-layer dense path against the rust sparse path).
#[test]
fn dense_agrees_with_mcprioq() {
    let Some(rt) = runtime() else { return };
    let dense = DenseXlaChain::new(rt, 63).unwrap();
    let sparse = crate::chain::McPrioQ::new(crate::chain::ChainConfig::default());
    let mut rng = crate::testutil::Rng64::new(0xE6);
    for _ in 0..2_000 {
        let src = rng.next_below(8);
        let u = rng.next_f64();
        let dst = 8 + ((u * u) * 40.0) as u64;
        dense.observe(src, dst);
        sparse.observe(src, dst);
    }
    for src in 0..8u64 {
        let a = sparse.infer_topk(src, 8);
        let b = dense.infer_topk(src, 8);
        assert_eq!(a.total, b.total, "src {src}");
        assert_eq!(a.items.len(), b.items.len(), "src {src}");
        for (x, y) in a.items.iter().zip(&b.items) {
            assert!((x.1 - y.1).abs() < 1e-5, "src {src}: {:?} vs {:?}", a.items, b.items);
        }
        for t in [0.3, 0.9] {
            let a = sparse.infer_threshold(src, t);
            let b = dense.infer_threshold(src, t);
            if a.items.len() <= dense.k() {
                assert_eq!(a.items.len(), b.items.len(), "src {src} t {t}");
                assert!((a.cumulative - b.cumulative).abs() < 1e-5, "src {src} t {t}");
            } else {
                // Fixed-shape constraint: the compiled artifact can return
                // at most k items; the answer truncates below t.
                assert_eq!(b.items.len(), dense.k(), "src {src} t {t}");
                assert!(b.cumulative < t, "src {src} t {t}");
            }
        }
    }
}

#[test]
fn dense_partial_batch_flush_is_correct() {
    let Some(rt) = runtime() else { return };
    let dense = DenseXlaChain::new(rt, 16).unwrap();
    // A single observation (batch of 1, padded with 7 parked writes).
    dense.observe(0, 1);
    let r = dense.infer_topk(0, 4);
    assert_eq!(r.total, 1);
    assert_eq!(r.items, vec![(1, 1.0)]);
    // Parked cell must not pollute any usable row.
    for src in 0..dense.usable_capacity() as u64 {
        if src != 0 {
            assert!(dense.infer_topk(src, 4).items.is_empty(), "src {src} polluted");
        }
    }
}

#[test]
fn dense_resident_bytes_quadratic() {
    let Some(rt) = runtime() else { return };
    let small = DenseXlaChain::new(rt.clone(), 16).unwrap();
    let big = DenseXlaChain::new(rt, 200).unwrap();
    assert_eq!(small.resident_bytes(), 64 * 64 * 4);
    assert_eq!(big.resident_bytes(), 256 * 256 * 4);
}
} // mod pjrt
