//! Artifact manifest parsing — xla-independent, so manifest inspection
//! (and its tests) work even when the `xla` feature is disabled.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// The three entry points the AOT pipeline emits per size variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    Infer,
    Update,
    Decay,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "infer" => ArtifactKind::Infer,
            "update" => ArtifactKind::Update,
            "decay" => ArtifactKind::Decay,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// One manifest line: `kind n b k filename`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub kind: ArtifactKind,
    /// Dense node capacity (matrix is n x n).
    pub n: usize,
    /// Batch size (0 where not applicable).
    pub b: usize,
    /// Top-k items (0 where not applicable).
    pub k: usize,
    pub file: String,
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 5 {
                bail!("manifest line {}: expected 5 fields, got {}", i + 1, parts.len());
            }
            entries.push(ArtifactMeta {
                kind: ArtifactKind::parse(parts[0])?,
                n: parts[1].parse().context("n")?,
                b: parts[2].parse().context("b")?,
                k: parts[3].parse().context("k")?,
                file: parts[4].to_string(),
            });
        }
        if entries.is_empty() {
            bail!("manifest is empty");
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Dense capacities available, ascending.
    pub fn capacities(&self) -> Vec<usize> {
        let mut ns: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::Infer)
            .map(|e| e.n)
            .collect();
        ns.sort_unstable();
        ns.dedup();
        ns
    }

    /// Smallest variant with capacity >= `nodes`.
    pub fn variant_for(&self, nodes: usize) -> Option<usize> {
        self.capacities().into_iter().find(|&n| n >= nodes)
    }

    pub fn entry(&self, kind: ArtifactKind, n: usize) -> Option<&ArtifactMeta> {
        self.entries.iter().find(|e| e.kind == kind && e.n == n)
    }
}
