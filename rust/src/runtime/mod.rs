//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes
//! them from rust. Python never runs at serving time.
//!
//! Pattern (see /opt/xla-example/load_hlo/): HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`/`execute_b`. Text is the interchange
//! format because jax ≥ 0.5 serialized protos use 64-bit instruction ids
//! that xla_extension 0.5.1 rejects.
//!
//! [`DenseXlaChain`] is the dense-matrix comparator of experiment E6: the
//! full counts matrix lives as a PJRT device buffer; updates, decay and
//! inference are each one executable call. The update/decay artifacts are
//! lowered *untupled*, so their output buffer is fed straight back as the
//! next call's input — the dense state never round-trips through the host
//! on the update path.
//!
//! The PJRT path needs the external `xla` crate and is gated behind the
//! `xla` cargo feature; without it (the offline default) an API-identical
//! stub is compiled whose `XlaRuntime::new` always fails, and every caller
//! skips the dense path (see `stub.rs`).

mod affinity;
pub mod backoff;
#[cfg(feature = "xla")]
mod dense;
#[cfg(feature = "xla")]
mod loader;
mod manifest;
#[cfg(not(feature = "xla"))]
mod stub;

pub use affinity::pin_current_thread;
pub use backoff::RetryPolicy;
#[cfg(feature = "xla")]
pub use dense::DenseXlaChain;
#[cfg(feature = "xla")]
pub use loader::{BufferBox, ExeHandle, XlaRuntime};
pub use manifest::{ArtifactKind, ArtifactMeta, Manifest};
#[cfg(not(feature = "xla"))]
pub use stub::{BufferBox, DenseXlaChain, ExeHandle, XlaRuntime};

/// Resolve the artifacts directory: `$MCPRIOQ_ARTIFACTS` or `./artifacts`
/// (relative to the workspace root, where `make artifacts` puts them).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    match std::env::var("MCPRIOQ_ARTIFACTS") {
        Ok(p) => p.into(),
        Err(_) => "artifacts".into(),
    }
}

#[cfg(test)]
mod tests;
