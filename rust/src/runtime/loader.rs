//! HLO compilation and PJRT execution (compiled only with the `xla`
//! feature; see `stub.rs` for the offline stand-in).
//!
//! Pattern (see /opt/xla-example/load_hlo/): HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`/`execute_b`. Text is the interchange
//! format because jax ≥ 0.5 serialized protos use 64-bit instruction ids
//! that xla_extension 0.5.1 rejects.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactKind, Manifest};

/// An opaque handle to a compiled executable in the runtime's cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExeHandle(usize);

/// A PJRT client plus a cache of compiled executables.
///
/// # Thread safety
/// The published `xla` crate's wrapper types are `!Send`/`!Sync` because
/// they hold an internal `Rc` to the client, even though the underlying
/// PJRT C++ client is itself thread-safe. `XlaRuntime` restores soundness
/// by *confining every wrapper call* — compiles, host↔device transfers,
/// executions, buffer drops — behind one `Mutex`, so the `Rc` reference
/// count is never touched by two threads at once. All public methods take
/// the lock internally; buffers never escape (callers hold `ExeHandle`s
/// and pass/receive host vectors or locked buffer slots).
pub struct XlaRuntime {
    inner: Mutex<Inner>,
    manifest: Manifest,
    platform: String,
}

struct Inner {
    client: xla::PjRtClient,
    exes: Vec<xla::PjRtLoadedExecutable>,
    by_file: HashMap<String, ExeHandle>,
}

// SAFETY: all xla wrapper objects (and their internal Rc) are only ever
// touched while holding `inner`'s mutex; see the struct docs.
unsafe impl Send for XlaRuntime {}
// SAFETY: see the `Send` justification above.
unsafe impl Sync for XlaRuntime {}

/// A device buffer slot owned by the runtime's confinement domain. Obtain
/// via [`XlaRuntime::upload_f32`]; pass back to `execute_*`. The slot is
/// just an index into the caller's own storage — the runtime hands out the
/// actual buffer objects inside [`BufferBox`] so drops also serialize.
pub struct BufferBox {
    buf: Option<xla::PjRtBuffer>,
}

impl BufferBox {
    fn new(buf: xla::PjRtBuffer) -> Self {
        BufferBox { buf: Some(buf) }
    }

    /// An empty placeholder (used when tearing a live buffer out of a
    /// struct during Drop).
    pub fn poisoned() -> Self {
        BufferBox { buf: None }
    }

    fn get(&self) -> &xla::PjRtBuffer {
        self.buf.as_ref().expect("buffer already taken")
    }
}

// SAFETY: a BufferBox is only created/used/freed through XlaRuntime
// methods which hold the runtime mutex. A BufferBox dropped *outside*
// `XlaRuntime::drop_buffer` leaks its device memory instead of touching
// the client's Rc from an unlocked context (see `impl Drop`), so no code
// path can race the reference count.
unsafe impl Send for BufferBox {}
// SAFETY: see the `Send` justification above.
unsafe impl Sync for BufferBox {}

impl Drop for BufferBox {
    fn drop(&mut self) {
        if let Some(buf) = self.buf.take() {
            // Deliberate leak: freeing would decrement the client Rc outside
            // the confinement lock. Disciplined callers (DenseXlaChain) free
            // via XlaRuntime::drop_buffer; this path exists only for early
            // returns on error paths, where a small leak beats UB.
            std::mem::forget(buf);
        }
    }
}

impl XlaRuntime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let platform = client.platform_name();
        Ok(XlaRuntime {
            inner: Mutex::new(Inner { client, exes: Vec::new(), by_file: HashMap::new() }),
            manifest,
            platform,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.platform.clone()
    }

    /// Compile (or fetch the cached) executable for `kind` at capacity `n`.
    pub fn executable(&self, kind: ArtifactKind, n: usize) -> Result<ExeHandle> {
        let meta = self
            .manifest
            .entry(kind, n)
            .with_context(|| format!("no artifact for {kind:?} n={n}"))?
            .clone();
        let mut inner = self.inner.lock().unwrap();
        if let Some(&h) = inner.by_file.get(&meta.file) {
            return Ok(h);
        }
        let path = self.manifest.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe =
            inner.client.compile(&comp).with_context(|| format!("compiling {}", meta.file))?;
        inner.exes.push(exe);
        let h = ExeHandle(inner.exes.len() - 1);
        inner.by_file.insert(meta.file, h);
        Ok(h)
    }

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<BufferBox> {
        let inner = self.inner.lock().unwrap();
        Ok(BufferBox::new(inner.client.buffer_from_host_buffer(data, dims, None)?))
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<BufferBox> {
        let inner = self.inner.lock().unwrap();
        Ok(BufferBox::new(inner.client.buffer_from_host_buffer(data, dims, None)?))
    }

    /// Execute with buffer arguments; returns the single output buffer
    /// (array or tuple, per the artifact's lowering).
    pub fn execute(&self, exe: ExeHandle, args: &[&BufferBox]) -> Result<BufferBox> {
        let inner = self.inner.lock().unwrap();
        let bufs: Vec<&xla::PjRtBuffer> = args.iter().map(|b| b.get()).collect();
        let mut out = inner.exes[exe.0].execute_b(&bufs)?;
        if out.len() != 1 || out[0].len() != 1 {
            bail!("unexpected output arity {}x{}", out.len(), out.first().map_or(0, |v| v.len()));
        }
        Ok(BufferBox::new(out.remove(0).remove(0)))
    }

    /// Download a buffer as a (possibly tuple) literal, flattened into
    /// per-leaf f32/i32 vectors by the caller via [`Self::literal_parts`].
    pub fn download(&self, buf: &BufferBox) -> Result<xla::Literal> {
        let _inner = self.inner.lock().unwrap();
        Ok(buf.get().to_literal_sync()?)
    }

    /// Drop a buffer inside the confinement domain.
    pub fn drop_buffer(&self, mut buf: BufferBox) {
        let _inner = self.inner.lock().unwrap();
        buf.buf.take();
    }
}
