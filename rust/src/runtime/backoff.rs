//! One retry policy for every reconnect/retry loop in the system:
//! capped exponential backoff with deterministic jitter.
//!
//! Before this module each plane had its own ad-hoc loop — the client's
//! `connect_with_backoff` doubled from 10ms, the follower link slept a
//! flat 200ms between redials, and the WAL-retry task didn't exist. They
//! now share [`RetryPolicy`], so retry behavior is tested once and tuned
//! in one place.
//!
//! Jitter is *deterministic*: derived from `splitmix64(seed ^ attempt)`,
//! not a clock or an RNG, so a test that replays the same schedule gets
//! the same delays — the same reproducibility discipline as the fault
//! plans in `persist::io`. Jitter is subtractive (up to 25% below the
//! exponential value), keeping every delay `<= cap` by construction
//! while still de-synchronizing herds of retriers with distinct seeds.

use std::time::Duration;

/// Weyl-sequence mixer (public-domain splitmix64): a cheap, well-mixed
/// `u64 -> u64` used for jitter derivation.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Capped exponential backoff with deterministic subtractive jitter.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    base: Duration,
    cap: Duration,
    seed: u64,
}

impl RetryPolicy {
    pub const fn new(base: Duration, cap: Duration, seed: u64) -> RetryPolicy {
        RetryPolicy { base, cap, seed }
    }

    /// The dial/connect policy the wire clients historically used:
    /// 10ms doubling, capped at 1s.
    pub fn connect(seed: u64) -> RetryPolicy {
        RetryPolicy::new(Duration::from_millis(10), Duration::from_secs(1), seed)
    }

    /// The WAL-retry / degraded-heal policy: 50ms doubling, capped at 2s
    /// so a transient disk fault is reprobed promptly but a dead disk
    /// isn't hammered.
    pub fn wal_retry(seed: u64) -> RetryPolicy {
        RetryPolicy::new(Duration::from_millis(50), Duration::from_secs(2), seed)
    }

    /// Delay before retry number `attempt` (0-based):
    /// `min(base * 2^attempt, cap)` minus up to 25% deterministic jitter.
    pub fn delay(&self, attempt: u32) -> Duration {
        let base_ns = self.base.as_nanos().max(1) as u64;
        let cap_ns = self.cap.as_nanos().min(u64::MAX as u128) as u64;
        // u128 so a deep attempt can't shift bits off the top and wrap
        // back below the cap.
        let exp_ns = ((base_ns as u128) << attempt.min(64)).min(cap_ns as u128) as u64;
        let exp_ns = exp_ns.max(base_ns.min(cap_ns));
        let jitter_span = exp_ns / 4;
        let jitter = if jitter_span == 0 {
            0
        } else {
            splitmix64(self.seed ^ u64::from(attempt)) % (jitter_span + 1)
        };
        Duration::from_nanos(exp_ns - jitter)
    }

    /// Sleep for `delay(attempt)`.
    pub fn sleep(&self, attempt: u32) {
        std::thread::sleep(self.delay(attempt));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_then_caps() {
        let p = RetryPolicy::new(Duration::from_millis(10), Duration::from_millis(500), 1);
        // Jitter-free upper envelope doubles: compare successive upper
        // bounds via the no-jitter exponential, and assert the cap.
        let mut prev_upper = 0u128;
        for attempt in 0..16 {
            let d = p.delay(attempt);
            let upper = (10_000_000u128 << attempt.min(20)).min(500_000_000);
            assert!(d.as_nanos() <= upper, "attempt {attempt}: {d:?} > {upper}ns");
            assert!(
                d.as_nanos() * 4 >= upper * 3,
                "attempt {attempt}: {d:?} below 75% of {upper}ns"
            );
            assert!(upper >= prev_upper, "envelope must be monotone");
            prev_upper = upper;
        }
        // Deep attempts are pinned at (jittered) cap, never overflow.
        assert!(p.delay(200) <= Duration::from_millis(500));
        assert!(p.delay(200) >= Duration::from_millis(375));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = RetryPolicy::new(Duration::from_millis(10), Duration::from_secs(1), 42);
        let b = RetryPolicy::new(Duration::from_millis(10), Duration::from_secs(1), 42);
        let c = RetryPolicy::new(Duration::from_millis(10), Duration::from_secs(1), 43);
        let same: Vec<Duration> = (0..8).map(|i| a.delay(i)).collect();
        let again: Vec<Duration> = (0..8).map(|i| b.delay(i)).collect();
        let other: Vec<Duration> = (0..8).map(|i| c.delay(i)).collect();
        assert_eq!(same, again, "same seed, same schedule");
        assert_ne!(same, other, "different seeds de-synchronize");
    }

    #[test]
    fn zero_base_never_panics() {
        let p = RetryPolicy::new(Duration::ZERO, Duration::from_millis(1), 0);
        for attempt in 0..70 {
            let _ = p.delay(attempt);
        }
    }
}
