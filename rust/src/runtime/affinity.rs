//! Core affinity for shard-affine ingest workers (DESIGN.md §7).
//!
//! Shards are statically owned by workers (`shard % workers`), so a
//! worker's working set — its shards' dst tables, edge arenas, and hot
//! NodeStates — is private by construction. Pinning the worker to one
//! core keeps that working set resident in one L1/L2 instead of being
//! dragged across cores by the scheduler, and keeps the arena's
//! thread-affine blocks NUMA-local to the core that walks them.
//!
//! The process links no libc, so `sched_setaffinity(2)` is issued as a
//! raw syscall (x86_64 nr 203 / aarch64 nr 122). On other targets —
//! or when the syscall fails (cpusets, containers with restricted
//! masks) — pinning degrades to a no-op `Err`: affinity is an
//! optimization, never a correctness dependency, so callers log and
//! continue.

/// Pin the calling thread to `cpu` (logical CPU index). Returns the
/// negated errno on failure; `Err` is always recoverable.
// Not under Miri: inline asm cannot be interpreted, so Miri takes the
// ENOSYS stub below (affinity is an optimization, never correctness).
#[cfg(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64"),
    not(miri)
))]
pub fn pin_current_thread(cpu: usize) -> Result<(), i64> {
    // cpu_set_t is 1024 bits; one u64 word per 64 CPUs.
    let mut mask = [0u64; 16];
    if cpu >= mask.len() * 64 {
        return Err(-22); // EINVAL
    }
    mask[cpu / 64] = 1u64 << (cpu % 64);
    // sched_setaffinity(pid = 0 → calling thread, len, mask)
    // SAFETY: `mask` is a live 128-byte buffer and `len` is its exact
    // size; the kernel only reads it.
    let ret = unsafe {
        sched_setaffinity_raw(0, std::mem::size_of_val(&mask), mask.as_ptr() as usize)
    };
    if ret == 0 { Ok(()) } else { Err(ret) }
}

#[cfg(not(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64"),
    not(miri)
)))]
pub fn pin_current_thread(_cpu: usize) -> Result<(), i64> {
    Err(-38) // ENOSYS: unsupported platform, caller treats as "not pinned"
}

/// # Safety
///
/// `mask_ptr` must point to at least `len` readable bytes (the kernel
/// reads the cpu mask from it).
#[cfg(all(target_os = "linux", target_arch = "x86_64", not(miri)))]
unsafe fn sched_setaffinity_raw(pid: i64, len: usize, mask_ptr: usize) -> i64 {
    let nr: i64 = 203; // __NR_sched_setaffinity
    let ret: i64;
    // SAFETY: the Linux syscall ABI clobbers only rcx/r11 (declared);
    // mask validity is the caller's contract above.
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") nr => ret,
            in("rdi") pid,
            in("rsi") len,
            in("rdx") mask_ptr,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret
}

/// # Safety
///
/// `mask_ptr` must point to at least `len` readable bytes (the kernel
/// reads the cpu mask from it).
#[cfg(all(target_os = "linux", target_arch = "aarch64", not(miri)))]
unsafe fn sched_setaffinity_raw(pid: i64, len: usize, mask_ptr: usize) -> i64 {
    let nr: i64 = 122; // __NR_sched_setaffinity
    let ret: i64;
    // SAFETY: `svc #0` follows the aarch64 syscall ABI; mask validity is
    // the caller's contract above.
    unsafe {
        std::arch::asm!(
            "svc #0",
            in("x8") nr,
            inlateout("x0") pid => ret,
            in("x1") len,
            in("x2") mask_ptr,
            options(nostack),
        );
    }
    ret
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg(all(
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64"),
        not(miri)
    ))]
    fn pinning_succeeds_for_some_cpu() {
        // Containers/cpusets may forbid individual CPUs, so require only
        // that at least one of the first N logical CPUs accepts the pin.
        let n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let ok = (0..n).any(|cpu| pin_current_thread(cpu).is_ok());
        assert!(ok, "could not pin to any of the first {n} CPUs");
    }

    #[test]
    fn out_of_range_cpu_is_rejected() {
        assert!(pin_current_thread(64 * 1024).is_err());
    }
}
