//! The dense-matrix markov chain running on XLA — experiment E6's
//! comparator and the end of the three-layer pipeline
//! (Pallas kernel → JAX model → AOT HLO → this).

use std::sync::Arc;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::loader::{BufferBox, ExeHandle, XlaRuntime};
use super::manifest::ArtifactKind;
use crate::baselines::MarkovModel;
use crate::chain::Recommendation;

/// Dense engine state: the `n x n` counts matrix as a live PJRT buffer.
///
/// Serialized behind a mutex: the dense buffer is a single functional value
/// that each update/decay replaces, so operations are inherently
/// one-at-a-time — exactly the contrast with MCPrioQ's concurrent updates
/// that E1/E6 measure.
struct DenseState {
    counts: BufferBox,
    /// Buffered (src, dst) observations awaiting a batched scatter.
    pending: Vec<(i32, i32)>,
    /// Live (nonzero-count) edges, tracked host-side for `edge_count`.
    edges_hint: std::collections::HashSet<(i32, i32)>,
}

pub struct DenseXlaChain {
    rt: Arc<XlaRuntime>,
    n: usize,
    b: usize,
    k: usize,
    infer_exe: ExeHandle,
    update_exe: ExeHandle,
    decay_exe: ExeHandle,
    state: Mutex<DenseState>,
}

impl DenseXlaChain {
    /// Build a dense chain with capacity for `nodes` node ids (picks the
    /// smallest compiled variant that fits; one id is reserved for batch
    /// padding, see `usable_capacity`).
    pub fn new(rt: Arc<XlaRuntime>, nodes: usize) -> Result<Self> {
        let n = rt
            .manifest()
            .variant_for(nodes + 1)
            .with_context(|| format!("no dense artifact fits {nodes} nodes"))?;
        let infer_meta = rt.manifest().entry(ArtifactKind::Infer, n).unwrap().clone();
        let infer_exe = rt.executable(ArtifactKind::Infer, n)?;
        let update_exe = rt.executable(ArtifactKind::Update, n)?;
        let decay_exe = rt.executable(ArtifactKind::Decay, n)?;
        let zeros = vec![0f32; n * n];
        let counts = rt.upload_f32(&zeros, &[n, n]).context("allocating dense counts")?;
        Ok(DenseXlaChain {
            rt,
            n,
            b: infer_meta.b,
            k: infer_meta.k,
            infer_exe,
            update_exe,
            decay_exe,
            state: Mutex::new(DenseState {
                counts,
                pending: Vec::new(),
                edges_hint: std::collections::HashSet::new(),
            }),
        })
    }

    /// Compiled matrix dimension.
    pub fn capacity(&self) -> usize {
        self.n
    }

    /// Highest usable node id + 1 (the last id is the padding cell).
    pub fn usable_capacity(&self) -> usize {
        self.n - 1
    }

    pub fn batch_size(&self) -> usize {
        self.b
    }

    /// Maximum items per inference answer (fixed at AOT-compile time — a
    /// genuine constraint of fixed-shape accelerators, reported in E6).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Bytes resident in the dense representation (the E6 memory column).
    pub fn resident_bytes(&self) -> usize {
        self.n * self.n * std::mem::size_of::<f32>()
    }

    /// Fallible observe (the `MarkovModel` impl panics on failure; prefer
    /// this in library code).
    pub fn try_observe(&self, src: u64, dst: u64) -> Result<()> {
        if src as usize >= self.usable_capacity() || dst as usize >= self.usable_capacity() {
            bail!("node id out of dense capacity {}", self.usable_capacity());
        }
        let mut state = self.state.lock().unwrap();
        state.pending.push((src as i32, dst as i32));
        state.edges_hint.insert((src as i32, dst as i32));
        if state.pending.len() >= self.b {
            self.flush_locked(&mut state)?;
        }
        Ok(())
    }

    /// Flush pending observations through the scatter-add executable.
    /// Caller holds the state lock.
    fn flush_locked(&self, state: &mut DenseState) -> Result<()> {
        while !state.pending.is_empty() {
            let take = state.pending.len().min(self.b);
            let mut srcs: Vec<i32> = state.pending[..take].iter().map(|&(s, _)| s).collect();
            let mut dsts: Vec<i32> = state.pending[..take].iter().map(|&(_, d)| d).collect();
            state.pending.drain(..take);
            // Short batches pad into the parked cell (n-1, n-1): id n-1 is
            // reserved, so parked mass can never leak into a query row.
            while srcs.len() < self.b {
                srcs.push((self.n - 1) as i32);
                dsts.push((self.n - 1) as i32);
            }
            let src_buf = self.rt.upload_i32(&srcs, &[self.b])?;
            let dst_buf = self.rt.upload_i32(&dsts, &[self.b])?;
            let new_counts =
                self.rt.execute(self.update_exe, &[&state.counts, &src_buf, &dst_buf])?;
            self.rt.drop_buffer(src_buf);
            self.rt.drop_buffer(dst_buf);
            let old = std::mem::replace(&mut state.counts, new_counts);
            self.rt.drop_buffer(old);
        }
        Ok(())
    }

    fn infer(&self, src: u64, mode: InferMode) -> Result<Recommendation> {
        let empty = Recommendation { items: vec![], cumulative: 0.0, scanned: 0, total: 0 };
        if src as usize >= self.usable_capacity() {
            return Ok(empty);
        }
        let mut state = self.state.lock().unwrap();
        self.flush_locked(&mut state)?;
        let queries = vec![src as i32; self.b];
        let qbuf = self.rt.upload_i32(&queries, &[self.b])?;
        let out = self.rt.execute(self.infer_exe, &[&state.counts, &qbuf])?;
        self.rt.drop_buffer(qbuf);
        let tuple = self.rt.download(&out)?;
        self.rt.drop_buffer(out);
        drop(state);

        let (ids_l, probs_l, cum_l, totals_l) = tuple.to_tuple4()?;
        let ids = ids_l.to_vec::<i32>()?;
        let probs = probs_l.to_vec::<f32>()?;
        let cums = cum_l.to_vec::<f32>()?;
        let total = totals_l.to_vec::<f32>()?[0] as u64;

        // Row 0 of the batch is our query (all rows identical).
        let mut items = Vec::new();
        let mut cumulative = 0.0f64;
        let mut scanned = 0usize;
        for i in 0..self.k {
            let p = probs[i] as f64;
            if p <= 0.0 {
                break; // ran out of live edges
            }
            scanned += 1;
            items.push((ids[i] as u64, p));
            cumulative = cums[i] as f64;
            match mode {
                InferMode::Threshold(t) => {
                    if cumulative >= t {
                        break;
                    }
                }
                InferMode::TopK(k) => {
                    if items.len() >= k {
                        break;
                    }
                }
            }
        }
        if matches!(mode, InferMode::Threshold(t) if t <= 0.0) {
            items.clear();
            cumulative = 0.0;
            scanned = 0;
        }
        Ok(Recommendation { items, cumulative, scanned, total })
    }

    fn decay_impl(&self) -> Result<(u64, usize)> {
        let mut state = self.state.lock().unwrap();
        self.flush_locked(&mut state)?;
        let new_counts = self.rt.execute(self.decay_exe, &[&state.counts])?;
        let old = std::mem::replace(&mut state.counts, new_counts);
        self.rt.drop_buffer(old);
        // Dense decay reports surviving mass by reading the matrix back
        // (maintenance path only; the O(n²) readback is part of the dense
        // engine's honest cost profile, recorded in E6).
        let lit = self.rt.download(&state.counts)?;
        let host = lit.to_vec::<f32>()?;
        let park = (self.n - 1) * self.n + (self.n - 1);
        let total: f64 =
            host.iter().enumerate().filter(|&(i, _)| i != park).map(|(_, &x)| x as f64).sum();
        let before = state.edges_hint.len();
        let n = self.n;
        state.edges_hint.retain(|&(s, d)| host[s as usize * n + d as usize] > 0.0);
        let pruned = before - state.edges_hint.len();
        Ok((total as u64, pruned))
    }
}

#[derive(Clone, Copy)]
enum InferMode {
    Threshold(f64),
    TopK(usize),
}

impl MarkovModel for DenseXlaChain {
    fn name(&self) -> &'static str {
        "dense-xla"
    }

    fn observe(&self, src: u64, dst: u64) {
        self.try_observe(src, dst).expect("dense observe failed");
    }

    fn infer_threshold(&self, src: u64, threshold: f64) -> Recommendation {
        self.infer(src, InferMode::Threshold(threshold.clamp(0.0, 1.0)))
            .expect("dense inference failed")
    }

    fn infer_topk(&self, src: u64, k: usize) -> Recommendation {
        if k == 0 {
            return Recommendation { items: vec![], cumulative: 0.0, scanned: 0, total: 0 };
        }
        self.infer(src, InferMode::TopK(k)).expect("dense inference failed")
    }

    fn decay(&self) -> (u64, usize) {
        self.decay_impl().expect("dense decay failed")
    }

    fn edge_count(&self) -> usize {
        self.state.lock().unwrap().edges_hint.len()
    }
}

impl Drop for DenseXlaChain {
    fn drop(&mut self) {
        // Free the live counts buffer inside the confinement lock.
        let state = self.state.get_mut().unwrap();
        let counts = std::mem::replace(
            &mut state.counts,
            BufferBox::poisoned(),
        );
        self.rt.drop_buffer(counts);
    }
}
