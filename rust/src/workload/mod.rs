//! Workload generators for the experiment suite (DESIGN.md §3).
//!
//! The paper evaluates on proprietary telecom mobility data (ref [1]) and
//! motivates recommender workloads; neither is available, so this module
//! provides the synthetic equivalents documented in DESIGN.md
//! §Substitutions: Zipf-distributed edge preferences (the paper's "oftentimes
//! the edges follow a Zipf distribution"), a hex-grid cellular mobility
//! model, and recsys session streams.

mod mobility;
mod recsys;
mod zipf;

pub use mobility::{MobilityConfig, MobilityTrace, Topology};
pub use recsys::{RecsysConfig, SessionStream};
pub use zipf::Zipf;

use crate::testutil::Rng64;

/// A stream of `(src, dst)` transition observations.
pub trait TransitionStream {
    fn next_transition(&mut self) -> (u64, u64);
    /// Fill a batch (convenience for benches).
    fn batch(&mut self, n: usize) -> Vec<(u64, u64)> {
        (0..n).map(|_| self.next_transition()).collect()
    }
}

/// Markov transitions where every node has `fanout` candidate successors
/// whose selection probability is Zipf(s). The canonical E1-E4 workload:
/// `s = 0` gives the uniform worst case, `s = 1.2` the skewed normal case.
pub struct ZipfChainStream {
    nodes: u64,
    zipf: Zipf,
    rng: Rng64,
    cur: u64,
    /// Successor of node `v` at rank `r` is `((v * MIX) ^ salt + r + 1)
    /// % nodes` — a deterministic pseudo-random fanout without storing the
    /// graph. `salt` derives from the seed, so two streams with different
    /// seeds are different *topologies* (E5 uses this as the drift event).
    fanout: u64,
    salt: u64,
}

const MIX: u64 = 0x5851_F42D_4C95_7F2D;

impl ZipfChainStream {
    pub fn new(nodes: u64, fanout: u64, s: f64, seed: u64) -> Self {
        Self::with_topology(nodes, fanout, s, seed, seed)
    }

    /// Separate RNG stream and topology: streams sharing `topo_seed` walk
    /// the *same* graph with independent randomness (multi-threaded benches
    /// must use this, or each thread invents its own edge set).
    pub fn with_topology(nodes: u64, fanout: u64, s: f64, rng_seed: u64, topo_seed: u64) -> Self {
        assert!(nodes > 1 && fanout >= 1);
        ZipfChainStream {
            nodes,
            zipf: Zipf::new(fanout as usize, s),
            rng: Rng64::new(rng_seed),
            cur: 0,
            fanout,
            salt: topo_seed.wrapping_mul(0xD6E8_FEB8_6659_FD93),
        }
    }

    /// The dst of `src` at preference rank `rank` (0 = most likely).
    pub fn dst_at_rank(&self, src: u64, rank: u64) -> u64 {
        ((src.wrapping_mul(MIX) ^ self.salt).wrapping_add(rank + 1)) % self.nodes
    }

    pub fn nodes(&self) -> u64 {
        self.nodes
    }

    pub fn fanout(&self) -> u64 {
        self.fanout
    }
}

impl TransitionStream for ZipfChainStream {
    fn next_transition(&mut self) -> (u64, u64) {
        let src = self.cur;
        let rank = self.zipf.sample(&mut self.rng) as u64;
        let dst = self.dst_at_rank(src, rank);
        self.cur = dst;
        (src, dst)
    }
}

/// Uniform random `(src, dst)` pairs over disjoint node sets — stress-test
/// stream with no markov structure (hash-table-heavy, worst case).
pub struct UniformPairs {
    srcs: u64,
    dsts: u64,
    rng: Rng64,
}

impl UniformPairs {
    pub fn new(srcs: u64, dsts: u64, seed: u64) -> Self {
        UniformPairs { srcs, dsts, rng: Rng64::new(seed) }
    }
}

impl TransitionStream for UniformPairs {
    fn next_transition(&mut self) -> (u64, u64) {
        (self.rng.next_below(self.srcs), self.rng.next_below(self.dsts))
    }
}

#[cfg(test)]
mod tests;
