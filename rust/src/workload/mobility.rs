//! Synthetic cellular mobility traces — the substitute for the paper's
//! proprietary 5G-core data (ref [1], DESIGN.md §Substitutions).
//!
//! Topology: a hex-like grid of cells, each with up to 6 neighbours. Users
//! perform markov walks: from cell `c` they move to one of its neighbours
//! with Zipf-skewed, per-cell-stable preferences (commuter corridors), with
//! a small uniform exploration probability. A *topology flip* re-permutes
//! the preference ranks — the drift event used by E5 (model decay) and E8
//! (paging under drift).

use super::zipf::Zipf;
use crate::testutil::Rng64;

/// Hex-ish grid of `width x height` cells.
#[derive(Debug, Clone)]
pub struct Topology {
    width: u64,
    height: u64,
}

impl Topology {
    pub fn grid(width: u64, height: u64) -> Self {
        assert!(width >= 2 && height >= 2);
        Topology { width, height }
    }

    pub fn cells(&self) -> u64 {
        self.width * self.height
    }

    /// Neighbours of a cell (4-8 depending on position; hex-like
    /// connectivity: E, W, N, S, NE, SW).
    pub fn neighbours(&self, cell: u64) -> Vec<u64> {
        let (x, y) = (cell % self.width, cell / self.width);
        let mut out = Vec::with_capacity(6);
        let deltas: [(i64, i64); 6] = [(1, 0), (-1, 0), (0, 1), (0, -1), (1, 1), (-1, -1)];
        for (dx, dy) in deltas {
            let nx = x as i64 + dx;
            let ny = y as i64 + dy;
            if nx >= 0 && nx < self.width as i64 && ny >= 0 && ny < self.height as i64 {
                out.push(ny as u64 * self.width + nx as u64);
            }
        }
        out
    }
}

#[derive(Debug, Clone)]
pub struct MobilityConfig {
    pub width: u64,
    pub height: u64,
    pub users: usize,
    /// Zipf exponent of neighbour preference (commuter-corridor skew).
    pub skew: f64,
    /// Probability of ignoring preferences and picking uniformly.
    pub explore: f64,
    pub seed: u64,
}

impl Default for MobilityConfig {
    fn default() -> Self {
        MobilityConfig { width: 16, height: 16, users: 200, skew: 1.1, explore: 0.05, seed: 7 }
    }
}

/// A running mobility simulation producing `(from_cell, to_cell)` handover
/// events, one user at a time (round-robin).
pub struct MobilityTrace {
    topo: Topology,
    zipf_by_degree: Vec<Zipf>,
    /// Per-cell permutation epoch: preference rank r maps to neighbour
    /// `perm[(cell, r)]`, reshuffled on `flip_topology`.
    flip_salt: u64,
    users: Vec<u64>,
    next_user: usize,
    rng: Rng64,
    config: MobilityConfig,
}

impl MobilityTrace {
    pub fn new(config: MobilityConfig) -> Self {
        let topo = Topology::grid(config.width, config.height);
        let mut rng = Rng64::new(config.seed);
        let users = (0..config.users).map(|_| rng.next_below(topo.cells())).collect();
        // Pre-build one Zipf per possible degree (1..=6).
        let zipf_by_degree = (1..=6).map(|d| Zipf::new(d, config.skew)).collect();
        MobilityTrace { topo, zipf_by_degree, flip_salt: 0, users, next_user: 0, rng, config }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Permute every cell's neighbour preferences — models a structural
    /// change (new road/venue/base station): the hot corridors move.
    pub fn flip_topology(&mut self) {
        self.flip_salt = self.flip_salt.wrapping_add(0x9E37_79B9);
    }

    /// Preferred neighbour of `cell` at rank `r` under the current epoch.
    fn preferred(&self, cell: u64, rank: usize, degree: usize) -> u64 {
        // Deterministic per-cell permutation: rotate by a salted hash.
        let h = cell
            .wrapping_mul(0xD6E8_FEB8_6659_FD93)
            .wrapping_add(self.flip_salt as u64)
            .wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
        let rot = (h >> 32) as usize % degree;
        let idx = (rank + rot) % degree;
        self.topo.neighbours(cell)[idx]
    }

    /// Ground-truth next-cell distribution for `cell` (used by E8 to score
    /// paging policies against the true model).
    pub fn true_distribution(&self, cell: u64) -> Vec<(u64, f64)> {
        let nbrs = self.topo.neighbours(cell);
        let d = nbrs.len();
        let z = &self.zipf_by_degree[d - 1];
        let mut probs = vec![0.0; d];
        for (rank, p) in (0..d).map(|r| (r, z.pmf(r))) {
            let dst = self.preferred(cell, rank, d);
            let i = nbrs.iter().position(|&n| n == dst).unwrap();
            // Mix in the exploration mass.
            probs[i] += p * (1.0 - self.config.explore) + self.config.explore / d as f64;
        }
        nbrs.into_iter().zip(probs).collect()
    }
}

impl super::TransitionStream for MobilityTrace {
    fn next_transition(&mut self) -> (u64, u64) {
        let uid = self.next_user;
        self.next_user = (self.next_user + 1) % self.users.len();
        let from = self.users[uid];
        let nbrs = self.topo.neighbours(from);
        let d = nbrs.len();
        let to = if self.rng.next_bool(self.config.explore) {
            nbrs[self.rng.next_below(d as u64) as usize]
        } else {
            let rank = self.zipf_by_degree[d - 1].sample(&mut self.rng);
            self.preferred(from, rank, d)
        };
        self.users[uid] = to;
        (from, to)
    }
}
