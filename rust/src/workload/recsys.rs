//! Synthetic recommender sessions (DESIGN.md §Substitutions): item-to-item
//! transitions with Zipf item popularity and per-item stable co-occurrence
//! preferences — the cumulative-threshold query workload of the paper's
//! introduction ("recommend items such that P(match) >= 90%").

use super::zipf::Zipf;
use crate::testutil::Rng64;

#[derive(Debug, Clone)]
pub struct RecsysConfig {
    pub items: u64,
    /// Candidate next-items per item.
    pub fanout: u64,
    /// Zipf exponent of next-item preference.
    pub skew: f64,
    /// Geometric session-continuation probability.
    pub continue_p: f64,
    pub seed: u64,
}

impl Default for RecsysConfig {
    fn default() -> Self {
        RecsysConfig { items: 5_000, fanout: 32, skew: 1.05, continue_p: 0.85, seed: 21 }
    }
}

/// Produces item-view sessions; `next_transition` yields consecutive
/// `(prev_item, item)` pairs, restarting sessions per `continue_p`.
pub struct SessionStream {
    config: RecsysConfig,
    popularity: Zipf,
    preference: Zipf,
    rng: Rng64,
    cur: Option<u64>,
    sessions: u64,
}

const MIX: u64 = 0x2545_F491_4F6C_DD1D;

impl SessionStream {
    pub fn new(config: RecsysConfig) -> Self {
        assert!(config.items > 1 && config.fanout >= 1);
        let popularity = Zipf::new(config.items as usize, 1.0);
        let preference = Zipf::new(config.fanout as usize, config.skew);
        let rng = Rng64::new(config.seed);
        SessionStream { config, popularity, preference, rng, cur: None, sessions: 0 }
    }

    /// Candidate next item of `item` at preference rank `r`.
    pub fn related_at_rank(&self, item: u64, rank: u64) -> u64 {
        (item.wrapping_mul(MIX).wrapping_add(rank * rank + 1)) % self.config.items
    }

    pub fn sessions_started(&self) -> u64 {
        self.sessions
    }

    fn start_session(&mut self) -> u64 {
        self.sessions += 1;
        self.popularity.sample(&mut self.rng) as u64
    }
}

impl super::TransitionStream for SessionStream {
    fn next_transition(&mut self) -> (u64, u64) {
        let prev = match self.cur {
            Some(i) if self.rng.next_bool(self.config.continue_p) => i,
            _ => self.start_session(),
        };
        let rank = self.preference.sample(&mut self.rng) as u64;
        let item = self.related_at_rank(prev, rank);
        self.cur = Some(item);
        (prev, item)
    }
}
