//! Workload generator tests.

use super::*;
use crate::testutil::Rng64;
use std::collections::HashMap;

#[test]
fn zipf_chain_stream_is_markov() {
    let mut s = ZipfChainStream::new(100, 8, 1.1, 1);
    let mut prev_dst = None;
    for _ in 0..1000 {
        let (src, dst) = s.next_transition();
        assert!(src < 100 && dst < 100);
        if let Some(p) = prev_dst {
            assert_eq!(src, p, "stream must chain src = previous dst");
        }
        prev_dst = Some(dst);
    }
}

#[test]
fn zipf_chain_stream_rank_zero_dominates() {
    let mut s = ZipfChainStream::new(50, 8, 1.3, 2);
    let mut by_src: HashMap<u64, HashMap<u64, u64>> = HashMap::new();
    for _ in 0..100_000 {
        let (src, dst) = s.next_transition();
        *by_src.entry(src).or_default().entry(dst).or_default() += 1;
    }
    // For sources with enough samples, the top dst must be the rank-0 dst.
    let mut checked = 0;
    for (src, dsts) in &by_src {
        let n: u64 = dsts.values().sum();
        if n < 2_000 {
            continue;
        }
        let top = dsts.iter().max_by_key(|&(_, c)| c).unwrap().0;
        assert_eq!(*top, s.dst_at_rank(*src, 0), "src {src}");
        checked += 1;
    }
    assert!(checked > 0, "no src accumulated enough mass");
}

#[test]
fn uniform_pairs_bounds() {
    let mut s = UniformPairs::new(10, 20, 3);
    for _ in 0..1000 {
        let (a, b) = s.next_transition();
        assert!(a < 10 && b < 20);
    }
}

#[test]
fn batch_has_requested_len() {
    let mut s = UniformPairs::new(4, 4, 9);
    assert_eq!(s.batch(17).len(), 17);
}

#[test]
fn topology_neighbours_symmetric_and_in_bounds() {
    let t = Topology::grid(8, 6);
    for cell in 0..t.cells() {
        let nbrs = t.neighbours(cell);
        assert!(!nbrs.is_empty() && nbrs.len() <= 6);
        for &n in &nbrs {
            assert!(n < t.cells());
            assert_ne!(n, cell);
            // Symmetric connectivity (deltas come in +/- pairs).
            assert!(t.neighbours(n).contains(&cell), "asymmetric {cell} -> {n}");
        }
    }
}

#[test]
fn mobility_transitions_follow_topology() {
    let mut m = MobilityTrace::new(MobilityConfig::default());
    for _ in 0..5_000 {
        let (from, to) = m.next_transition();
        assert!(
            m.topology().neighbours(from).contains(&to),
            "handover {from} -> {to} not adjacent"
        );
    }
}

#[test]
fn mobility_true_distribution_sums_to_one() {
    let m = MobilityTrace::new(MobilityConfig::default());
    for cell in [0u64, 5, 100, 255] {
        let d = m.true_distribution(cell);
        let sum: f64 = d.iter().map(|&(_, p)| p).sum();
        assert!((sum - 1.0).abs() < 1e-9, "cell {cell} sums to {sum}");
        assert!(d.iter().all(|&(_, p)| p > 0.0));
    }
}

#[test]
fn mobility_flip_changes_preferences() {
    let mut m = MobilityTrace::new(MobilityConfig { explore: 0.0, ..Default::default() });
    let before: Vec<_> = (0..50).map(|c| m.true_distribution(c)).collect();
    m.flip_topology();
    let after: Vec<_> = (0..50).map(|c| m.true_distribution(c)).collect();
    let changed = before
        .iter()
        .zip(&after)
        .filter(|(b, a)| {
            let top_b = b.iter().max_by(|x, y| x.1.total_cmp(&y.1)).unwrap().0;
            let top_a = a.iter().max_by(|x, y| x.1.total_cmp(&y.1)).unwrap().0;
            top_b != top_a
        })
        .count();
    assert!(changed > 10, "flip changed only {changed}/50 top preferences");
}

#[test]
fn mobility_empirical_matches_true_distribution() {
    let mut m = MobilityTrace::new(MobilityConfig {
        width: 4,
        height: 4,
        users: 50,
        skew: 1.0,
        explore: 0.1,
        seed: 5,
    });
    let mut counts: HashMap<u64, HashMap<u64, u64>> = HashMap::new();
    for _ in 0..300_000 {
        let (f, t) = m.next_transition();
        *counts.entry(f).or_default().entry(t).or_default() += 1;
    }
    // Compare the hottest cell's empirical next-hop distribution.
    let (cell, dsts) = counts.iter().max_by_key(|(_, d)| d.values().sum::<u64>()).unwrap();
    let n: u64 = dsts.values().sum();
    for (dst, p_true) in m.true_distribution(*cell) {
        let emp = *dsts.get(&dst).unwrap_or(&0) as f64 / n as f64;
        assert!(
            (emp - p_true).abs() < 0.05,
            "cell {cell}->{dst}: emp {emp:.3} vs true {p_true:.3}"
        );
    }
}

#[test]
fn sessions_restart_and_stay_in_range() {
    let mut s = SessionStream::new(RecsysConfig {
        items: 100,
        fanout: 8,
        skew: 1.0,
        continue_p: 0.5,
        seed: 4,
    });
    for _ in 0..10_000 {
        let (a, b) = s.next_transition();
        assert!(a < 100 && b < 100);
    }
    // With continue_p = 0.5, ~half the steps start a new session.
    let started = s.sessions_started();
    assert!(started > 3_000 && started < 7_000, "sessions {started}");
}

#[test]
fn recsys_transitions_deterministic_per_seed() {
    let cfg = RecsysConfig::default();
    let mut a = SessionStream::new(cfg.clone());
    let mut b = SessionStream::new(cfg);
    for _ in 0..100 {
        assert_eq!(a.next_transition(), b.next_transition());
    }
}

#[test]
fn zipf_chain_seed_determinism() {
    let mut a = ZipfChainStream::new(64, 6, 0.9, 42);
    let mut b = ZipfChainStream::new(64, 6, 0.9, 42);
    assert_eq!(a.batch(50), b.batch(50));
    let _ = Rng64::new(0); // keep import used
}
