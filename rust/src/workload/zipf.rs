//! Zipf(s) sampler over ranks `0..n` via inverse-CDF binary search.
//! `s = 0` degenerates to the uniform distribution (the paper's worst case
//! for inference cost); `s ≈ 1` is the "oftentimes" case of §II.B.

use crate::testutil::Rng64;

#[derive(Debug, Clone)]
pub struct Zipf {
    /// cdf[r] = P(rank <= r); cdf[n-1] == 1.0.
    cdf: Vec<f64>,
    s: f64,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "Zipf over empty support");
        assert!(s >= 0.0 && s.is_finite());
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for r in 1..=n {
            acc += 1.0 / (r as f64).powf(s);
            cdf.push(acc);
        }
        let norm = acc;
        for c in &mut cdf {
            *c /= norm;
        }
        Zipf { cdf, s }
    }

    pub fn n(&self) -> usize {
        self.cdf.len()
    }

    pub fn exponent(&self) -> f64 {
        self.s
    }

    /// Sample a rank in `0..n` (0 = most probable).
    #[inline]
    pub fn sample(&self, rng: &mut Rng64) -> usize {
        let u = rng.next_f64();
        // partition_point: first index with cdf[i] >= u.
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    /// P(rank == r).
    pub fn pmf(&self, r: usize) -> f64 {
        if r == 0 {
            self.cdf[0]
        } else {
            self.cdf[r] - self.cdf[r - 1]
        }
    }

    /// Quantile function: the number of top ranks needed to cover
    /// cumulative probability `t` — the paper's CDF⁻¹(t), i.e. the
    /// *predicted* inference scan depth (E2 compares measured vs this).
    pub fn quantile(&self, t: f64) -> usize {
        let t = t.clamp(0.0, 1.0);
        if t == 0.0 {
            return 0;
        }
        self.cdf.partition_point(|&c| c < t - 1e-12) + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_when_s_zero() {
        let z = Zipf::new(10, 0.0);
        for r in 0..10 {
            assert!((z.pmf(r) - 0.1).abs() < 1e-12);
        }
        assert_eq!(z.quantile(0.5), 5);
        assert_eq!(z.quantile(1.0), 10);
    }

    #[test]
    fn skewed_head_heavy() {
        let z = Zipf::new(100, 1.2);
        assert!(z.pmf(0) > 10.0 * z.pmf(50));
        // Top items cover most of the mass.
        assert!(z.quantile(0.5) < 10);
    }

    #[test]
    fn sample_matches_pmf() {
        let z = Zipf::new(20, 1.0);
        let mut rng = Rng64::new(42);
        let mut counts = [0u64; 20];
        const N: u64 = 200_000;
        for _ in 0..N {
            counts[z.sample(&mut rng)] += 1;
        }
        for r in 0..20 {
            let emp = counts[r] as f64 / N as f64;
            let theo = z.pmf(r);
            assert!(
                (emp - theo).abs() < 0.01,
                "rank {r}: empirical {emp:.4} vs pmf {theo:.4}"
            );
        }
    }

    #[test]
    fn quantile_monotone_and_bounded() {
        let z = Zipf::new(50, 0.8);
        let mut last = 0;
        for i in 0..=10 {
            let q = z.quantile(i as f64 / 10.0);
            assert!(q >= last);
            assert!(q <= 50);
            last = q;
        }
    }

    #[test]
    fn single_item_support() {
        let z = Zipf::new(1, 1.0);
        let mut rng = Rng64::new(1);
        assert_eq!(z.sample(&mut rng), 0);
        assert_eq!(z.quantile(0.9), 1);
    }
}
