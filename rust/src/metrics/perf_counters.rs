//! Hardware performance counters via `perf_event_open(2)` — the
//! attribution half of the mechanical-sympathy work (DESIGN.md §7):
//! every bench row reports IPC and cache/branch miss rates so a
//! throughput win can be traced to the microarchitectural effect that
//! produced it (fewer LLC misses from the Eytzinger layout, fewer
//! branch misses from the branchless descent) instead of guessed at.
//!
//! Design constraints:
//!
//! * **No libc** — the syscall is issued with inline asm, same pattern
//!   as `runtime::affinity`.
//! * **Graceful no-op** — `perf_event_open` is often unavailable
//!   (non-Linux, `perf_event_paranoid`, seccomp in CI containers).
//!   Every failure degrades to `available == false` with zeroed
//!   samples; callers print `-` columns and carry on.
//! * **Multi-threaded benches** — counters are opened with `inherit`,
//!   so threads spawned *after* `open()` (the bench harness spawns its
//!   workers per sample) are counted, and their totals fold into the
//!   parent's fd when they exit, before the harness takes its end
//!   snapshot. `inherit` is incompatible with `PERF_FORMAT_GROUP`
//!   reads, hence four independent fds rather than one group. Events
//!   start enabled (no `disabled` bit): an `ioctl(ENABLE)` would not
//!   propagate to already-spawned children, but deltas of two
//!   `read(2)` snapshots measure exactly the window between them.

/// One snapshot of the four counters. All zeros when unavailable.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PerfSample {
    pub cycles: u64,
    pub instructions: u64,
    pub llc_misses: u64,
    pub branch_misses: u64,
    /// False when any counter failed to open; derived metrics yield `None`.
    pub available: bool,
}

impl PerfSample {
    /// Counters elapsed since `earlier` (saturating, for PMU wraps).
    pub fn delta(&self, earlier: &PerfSample) -> PerfSample {
        PerfSample {
            cycles: self.cycles.saturating_sub(earlier.cycles),
            instructions: self.instructions.saturating_sub(earlier.instructions),
            llc_misses: self.llc_misses.saturating_sub(earlier.llc_misses),
            branch_misses: self.branch_misses.saturating_sub(earlier.branch_misses),
            available: self.available && earlier.available,
        }
    }

    /// Instructions per cycle.
    pub fn ipc(&self) -> Option<f64> {
        (self.available && self.cycles > 0)
            .then(|| self.instructions as f64 / self.cycles as f64)
    }

    /// Last-level-cache misses per 1000 instructions.
    pub fn llc_per_kinst(&self) -> Option<f64> {
        (self.available && self.instructions > 0)
            .then(|| self.llc_misses as f64 * 1000.0 / self.instructions as f64)
    }

    /// Branch misses per 1000 instructions.
    pub fn branch_miss_per_kinst(&self) -> Option<f64> {
        (self.available && self.instructions > 0)
            .then(|| self.branch_misses as f64 * 1000.0 / self.instructions as f64)
    }
}

/// Four hardware counters (cycles, instructions, LLC misses, branch
/// misses) scoped to the calling process and its future threads.
pub struct PerfCounters {
    fds: [i64; 4],
    available: bool,
}

impl PerfCounters {
    /// Open the counters. Never fails: on any error the handle reports
    /// `available() == false` and snapshots are zero.
    pub fn open() -> PerfCounters {
        imp::open()
    }

    pub fn available(&self) -> bool {
        self.available
    }

    /// Read the current counter values.
    pub fn snapshot(&self) -> PerfSample {
        if !self.available {
            return PerfSample::default();
        }
        let mut vals = [0u64; 4];
        for (fd, v) in self.fds.iter().zip(vals.iter_mut()) {
            match imp::read_u64(*fd) {
                Some(x) => *v = x,
                None => return PerfSample::default(),
            }
        }
        PerfSample {
            cycles: vals[0],
            instructions: vals[1],
            llc_misses: vals[2],
            branch_misses: vals[3],
            available: true,
        }
    }
}

impl Drop for PerfCounters {
    fn drop(&mut self) {
        for &fd in &self.fds {
            if fd >= 0 {
                imp::close(fd);
            }
        }
    }
}

// Not under Miri: raw-syscall inline asm cannot be interpreted, so Miri
// takes the always-unavailable stub below.
#[cfg(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64"),
    not(miri)
))]
mod imp {
    use super::PerfCounters;

    /// `struct perf_event_attr`, PERF_ATTR_SIZE_VER0 prefix (64 bytes) —
    /// the kernel accepts any historical size and zero-fills the rest.
    #[repr(C)]
    struct PerfEventAttr {
        type_: u32,
        size: u32,
        config: u64,
        sample_period: u64,
        sample_type: u64,
        read_format: u64,
        /// Bitfield word: inherit (1<<1) | exclude_kernel (1<<5) |
        /// exclude_hv (1<<6). NOT `disabled`: events run from open, and
        /// windows are measured as deltas of read() snapshots.
        flags: u64,
        wakeup_events: u32,
        bp_type: u32,
        config1: u64,
    }

    const _: () = assert!(std::mem::size_of::<PerfEventAttr>() == 64);

    const PERF_TYPE_HARDWARE: u32 = 0;
    /// PERF_COUNT_HW_*: cpu-cycles, instructions, cache-misses (= LLC
    /// misses for type HARDWARE), branch-misses.
    const CONFIGS: [u64; 4] = [0, 1, 3, 5];
    const FLAGS: u64 = (1 << 1) | (1 << 5) | (1 << 6);

    #[cfg(target_arch = "x86_64")]
    mod nr {
        pub const PERF_EVENT_OPEN: i64 = 298;
        pub const READ: i64 = 0;
        pub const CLOSE: i64 = 3;
    }
    #[cfg(target_arch = "aarch64")]
    mod nr {
        pub const PERF_EVENT_OPEN: i64 = 241;
        pub const READ: i64 = 63;
        pub const CLOSE: i64 = 57;
    }

    /// # Safety
    ///
    /// `nr` must be a valid syscall number and `a1..a5` arguments valid
    /// for it — in particular any pointer argument must point to memory
    /// of the size that syscall reads or writes.
    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall5(nr: i64, a1: i64, a2: i64, a3: i64, a4: i64, a5: i64) -> i64 {
        let ret: i64;
        // SAFETY: the Linux syscall ABI clobbers only rcx/r11 (declared);
        // argument validity is the caller's contract above.
        unsafe {
            std::arch::asm!(
                "syscall",
                inlateout("rax") nr => ret,
                in("rdi") a1,
                in("rsi") a2,
                in("rdx") a3,
                in("r10") a4,
                in("r8") a5,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack),
            );
        }
        ret
    }

    /// # Safety
    ///
    /// Same contract as the x86_64 variant: valid syscall number, valid
    /// arguments (pointers sized for what the syscall accesses).
    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall5(nr: i64, a1: i64, a2: i64, a3: i64, a4: i64, a5: i64) -> i64 {
        let ret: i64;
        // SAFETY: `svc #0` follows the aarch64 syscall ABI (x8 = nr,
        // x0-x4 = args, x0 = ret); argument validity is the caller's
        // contract above.
        unsafe {
            std::arch::asm!(
                "svc #0",
                in("x8") nr,
                inlateout("x0") a1 => ret,
                in("x1") a2,
                in("x2") a3,
                in("x3") a4,
                in("x4") a5,
                options(nostack),
            );
        }
        ret
    }

    pub(super) fn open() -> PerfCounters {
        let mut fds = [-1i64; 4];
        for (i, &config) in CONFIGS.iter().enumerate() {
            let attr = PerfEventAttr {
                type_: PERF_TYPE_HARDWARE,
                size: std::mem::size_of::<PerfEventAttr>() as u32,
                config,
                sample_period: 0,
                sample_type: 0,
                read_format: 0,
                flags: FLAGS,
                wakeup_events: 0,
                bp_type: 0,
                config1: 0,
            };
            // perf_event_open(&attr, pid=0 (this process), cpu=-1 (any),
            //                 group_fd=-1, flags=0)
            // SAFETY: `attr` is a live, correctly-sized perf_event_attr
            // (the kernel reads exactly `size` bytes of it); the scalar
            // arguments match the syscall signature.
            let fd = unsafe {
                syscall5(nr::PERF_EVENT_OPEN, &attr as *const _ as i64, 0, -1, -1, 0)
            };
            if fd < 0 {
                // All-or-nothing: partial counter sets would silently skew
                // the derived ratios (e.g. IPC from mismatched windows).
                for &f in fds.iter().take(i) {
                    close(f);
                }
                return PerfCounters { fds: [-1; 4], available: false };
            }
            fds[i] = fd;
        }
        PerfCounters { fds, available: true }
    }

    pub(super) fn read_u64(fd: i64) -> Option<u64> {
        let mut buf = 0u64;
        // SAFETY: `buf` is 8 writable bytes and we ask read(2) for
        // exactly 8; a bad fd just returns -EBADF.
        let n = unsafe {
            syscall5(nr::READ, fd, &mut buf as *mut u64 as i64, 8, 0, 0)
        };
        (n == 8).then_some(buf)
    }

    pub(super) fn close(fd: i64) {
        // SAFETY: close(2) takes no pointers; a bad fd is a benign error.
        unsafe { syscall5(nr::CLOSE, fd, 0, 0, 0, 0) };
    }
}

#[cfg(not(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64"),
    not(miri)
)))]
mod imp {
    use super::PerfCounters;

    pub(super) fn open() -> PerfCounters {
        PerfCounters { fds: [-1; 4], available: false }
    }

    pub(super) fn read_u64(_fd: i64) -> Option<u64> {
        None
    }

    pub(super) fn close(_fd: i64) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unavailable_counters_degrade_to_zero() {
        // Whether or not the kernel grants the events, the API contract
        // holds: snapshot never errors, derived metrics are None when
        // unavailable or empty.
        let pc = PerfCounters::open();
        let s = pc.snapshot();
        if !pc.available() {
            assert_eq!(s, PerfSample::default());
            assert_eq!(s.ipc(), None);
            assert_eq!(s.llc_per_kinst(), None);
        }
    }

    #[test]
    fn deltas_measure_a_busy_window() {
        let pc = PerfCounters::open();
        if !pc.available() {
            return; // no perf here (paranoid/seccomp/non-Linux): nothing to assert
        }
        let a = pc.snapshot();
        // Burn some instructions so the window is provably non-empty.
        let mut x = 0u64;
        for i in 0..1_000_000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(x);
        let b = pc.snapshot();
        let d = b.delta(&a);
        assert!(d.available);
        assert!(d.instructions > 0, "instruction counter did not advance: {d:?}");
        assert!(d.ipc().unwrap() > 0.0);
    }

    #[test]
    fn inherit_counts_child_threads() {
        let pc = PerfCounters::open();
        if !pc.available() {
            return;
        }
        let a = pc.snapshot();
        let h = std::thread::spawn(|| {
            let mut x = 0u64;
            for i in 0..2_000_000u64 {
                x = x.wrapping_mul(2862933555777941757).wrapping_add(i);
            }
            std::hint::black_box(x)
        });
        h.join().unwrap();
        // The child exited before this snapshot, so its counts have folded
        // into the inherited fds.
        let d = pc.snapshot().delta(&a);
        assert!(d.instructions > 1_000_000, "child-thread work not attributed: {d:?}");
    }
}
