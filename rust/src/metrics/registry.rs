//! Named metric registry + Prometheus text exposition (DESIGN.md §9).
//!
//! One [`Registry`] per engine holds every named family the process
//! exports: counters, gauges, and latency histograms (exposed as
//! Prometheus *summaries* — quantiles + sum + count — because the
//! log-bucketed [`Histogram`] already computes percentiles and shipping
//! 2048 raw buckets per family would swamp the scrape).
//!
//! Concurrency model: **registration and exposition are cold** (a mutex
//! over the family list), **recording is hot and lock-free** — `counter`/
//! `gauge`/`histogram` return the `Arc` of the underlying atomic metric,
//! which the owning subsystem stores in a field and hits directly; the
//! registry holds a clone of the same `Arc` purely for rendering. Derived
//! values (queue depths, arena occupancy, RCU backlog, health rung) are
//! registered as *sampled closures* evaluated only at exposition time, so
//! they cost nothing between scrapes. Closures that need the engine hold
//! a `Weak` (the engine owns the registry — a strong capture would leak
//! the whole process).
//!
//! Exposition grammar (Prometheus text format 0.0.4): per family one
//! `# HELP` + `# TYPE` line, then one sample line per labeled series.
//! Label values escape `\`, `"`, and newline. Families render in
//! registration order — deterministic output, stable diffs.

use std::fmt::Write as _;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use super::{Counter, Gauge, Histogram, Snapshot};

type U64Fn = Box<dyn Fn() -> u64 + Send + Sync>;
type F64Fn = Box<dyn Fn() -> f64 + Send + Sync>;
type SnapFn = Box<dyn Fn() -> Snapshot + Send + Sync>;

/// Prometheus metric family type (the `# TYPE` line).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
    Summary,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Summary => "summary",
        }
    }
}

/// How one labeled series produces its sample(s) at exposition time.
enum Value {
    Counter(Arc<Counter>),
    CounterFn(U64Fn),
    Gauge(Arc<Gauge>),
    GaugeFn(F64Fn),
    /// Rendered as a summary: quantile series + `_sum` + `_count`.
    Histogram(Arc<Histogram>),
    /// A summary sampled from a closure (histograms owned elsewhere,
    /// e.g. the per-shard snapshot-rebuild timers inside the chain).
    SummaryFn(SnapFn),
}

/// One labeled series inside a family. `labels` is the pre-rendered inner
/// label block (`shard="3"`) — built once at registration so exposition
/// does no per-scrape label formatting.
struct Series {
    labels: String,
    value: Value,
}

struct Family {
    name: String,
    help: String,
    kind: Kind,
    series: Vec<Series>,
}

/// Process/engine-wide named metric registry. See the module docs for the
/// concurrency model. Cheap to share (`Arc<Registry>`).
#[derive(Default)]
pub struct Registry {
    families: Mutex<Vec<Family>>,
}

/// Escape a label value per the Prometheus text format: `\` → `\\`,
/// `"` → `\"`, newline → `\n`.
pub fn escape_label_value(out: &mut String, value: &str) {
    for c in value.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
}

fn render_labels(labels: &[(&str, &str)]) -> String {
    let mut out = String::new();
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        escape_label_value(&mut out, v);
        out.push('"');
    }
    out
}

fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name.bytes().enumerate().all(|(i, b)| {
            b.is_ascii_alphabetic() || b == b'_' || b == b':' || (i > 0 && b.is_ascii_digit())
        })
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Non-poisoning lock (same discipline as the queues): a panic while
    /// rendering must not wedge every later scrape.
    fn locked(&self) -> MutexGuard<'_, Vec<Family>> {
        self.families.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Find-or-create the family, then hand the (existing or new) series
    /// slot to `reuse`/`fresh`. Returns whatever the callback produces.
    fn series<R>(
        &self,
        name: &str,
        help: &str,
        kind: Kind,
        labels: &[(&str, &str)],
        reuse: impl FnOnce(&mut Value) -> Option<R>,
        fresh: impl FnOnce() -> (Value, R),
    ) -> R {
        debug_assert!(valid_name(name), "bad metric name {name:?}");
        let rendered = render_labels(labels);
        let mut families = self.locked();
        let fam = match families.iter_mut().find(|f| f.name == name) {
            Some(f) => {
                assert!(
                    f.kind == kind,
                    "metric {name:?} registered as {} and {}",
                    f.kind.as_str(),
                    kind.as_str()
                );
                f
            }
            None => {
                families.push(Family {
                    name: name.to_string(),
                    help: help.to_string(),
                    kind,
                    series: Vec::new(),
                });
                families.last_mut().expect("just pushed")
            }
        };
        if let Some(s) = fam.series.iter_mut().find(|s| s.labels == rendered) {
            if let Some(r) = reuse(&mut s.value) {
                return r;
            }
            // Same (name, labels) re-registered with a different value
            // shape: the latest registration wins (restarted subsystems
            // re-register their closures).
            let (value, r) = fresh();
            s.value = value;
            return r;
        }
        let (value, r) = fresh();
        fam.series.push(Series { labels: rendered, value });
        r
    }

    /// Get-or-register a counter series. Recording goes through the
    /// returned `Arc` — lock-free, no registry involvement.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        self.series(
            name,
            help,
            Kind::Counter,
            labels,
            |v| match v {
                Value::Counter(c) => Some(Arc::clone(c)),
                _ => None,
            },
            || {
                let c = Arc::new(Counter::new());
                (Value::Counter(Arc::clone(&c)), c)
            },
        )
    }

    /// Get-or-register a gauge series (set/get through the returned `Arc`).
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        self.series(
            name,
            help,
            Kind::Gauge,
            labels,
            |v| match v {
                Value::Gauge(g) => Some(Arc::clone(g)),
                _ => None,
            },
            || {
                let g = Arc::new(Gauge::new());
                (Value::Gauge(Arc::clone(&g)), g)
            },
        )
    }

    /// Get-or-register a latency histogram series, exposed as a summary.
    pub fn histogram(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        self.series(
            name,
            help,
            Kind::Summary,
            labels,
            |v| match v {
                Value::Histogram(h) => Some(Arc::clone(h)),
                _ => None,
            },
            || {
                let h = Arc::new(Histogram::new());
                (Value::Histogram(Arc::clone(&h)), h)
            },
        )
    }

    /// Register a sampled counter: `f` is evaluated at exposition time.
    /// For monotonic totals owned elsewhere (striped counters, WAL state).
    pub fn counter_fn(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        f: impl Fn() -> u64 + Send + Sync + 'static,
    ) {
        self.series(name, help, Kind::Counter, labels, |_| None, || {
            (Value::CounterFn(Box::new(f)), ())
        })
    }

    /// Register a sampled gauge (queue depth, occupancy, rung, ages…).
    pub fn gauge_fn(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        f: impl Fn() -> f64 + Send + Sync + 'static,
    ) {
        self.series(name, help, Kind::Gauge, labels, |_| None, || (Value::GaugeFn(Box::new(f)), ()))
    }

    /// Register a sampled summary (a histogram snapshot owned elsewhere).
    pub fn summary_fn(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        f: impl Fn() -> Snapshot + Send + Sync + 'static,
    ) {
        self.series(name, help, Kind::Summary, labels, |_| None, || {
            (Value::SummaryFn(Box::new(f)), ())
        })
    }

    /// Render the whole registry in Prometheus text format into `out`
    /// (appended; caller clears). Families in registration order.
    pub fn render_into(&self, out: &mut String) {
        fn sample(out: &mut String, name: &str, labels: &str, extra: Option<(&str, &str)>) {
            out.push_str(name);
            let has_extra = extra.is_some();
            if !labels.is_empty() || has_extra {
                out.push('{');
                out.push_str(labels);
                if let Some((k, v)) = extra {
                    if !labels.is_empty() {
                        out.push(',');
                    }
                    out.push_str(k);
                    out.push_str("=\"");
                    out.push_str(v);
                    out.push('"');
                }
                out.push('}');
            }
            out.push(' ');
        }
        fn summary(out: &mut String, name: &str, labels: &str, s: Snapshot) {
            for (q, v) in
                [("0.5", s.p50), ("0.9", s.p90), ("0.99", s.p99), ("0.999", s.p999)]
            {
                sample(out, name, labels, Some(("quantile", q)));
                let _ = writeln!(out, "{v}");
            }
            sample(out, &format!("{name}_sum"), labels, None);
            let _ = writeln!(out, "{}", s.sum);
            sample(out, &format!("{name}_count"), labels, None);
            let _ = writeln!(out, "{}", s.count);
        }
        let families = self.locked();
        for fam in families.iter() {
            let _ = writeln!(out, "# HELP {} {}", fam.name, fam.help);
            let _ = writeln!(out, "# TYPE {} {}", fam.name, fam.kind.as_str());
            for s in &fam.series {
                match &s.value {
                    Value::Counter(c) => {
                        sample(out, &fam.name, &s.labels, None);
                        let _ = writeln!(out, "{}", c.get());
                    }
                    Value::CounterFn(f) => {
                        sample(out, &fam.name, &s.labels, None);
                        let _ = writeln!(out, "{}", f());
                    }
                    Value::Gauge(g) => {
                        sample(out, &fam.name, &s.labels, None);
                        let _ = writeln!(out, "{}", g.get());
                    }
                    Value::GaugeFn(f) => {
                        sample(out, &fam.name, &s.labels, None);
                        let v = f();
                        let _ = writeln!(out, "{v}");
                    }
                    Value::Histogram(h) => summary(out, &fam.name, &s.labels, h.snapshot()),
                    Value::SummaryFn(f) => summary(out, &fam.name, &s.labels, f()),
                }
            }
        }
    }

    /// Convenience for tests / the wire verb: render to a fresh string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_get_or_register_returns_same_atomic() {
        let r = Registry::new();
        let a = r.counter("test_total", "help", &[("shard", "0")]);
        let b = r.counter("test_total", "help", &[("shard", "0")]);
        a.add(3);
        assert_eq!(b.get(), 3, "same (name, labels) must share the atomic");
        let c = r.counter("test_total", "help", &[("shard", "1")]);
        c.inc();
        assert_eq!(c.get(), 1);
        assert_eq!(a.get(), 3, "different labels are distinct series");
    }

    #[test]
    fn exposition_format_conformance() {
        let r = Registry::new();
        r.counter("mc_requests_total", "Requests served.", &[("shard", "0")]).add(7);
        r.gauge("mc_depth", "Queue depth.", &[]).set(42);
        r.gauge_fn("mc_rate", "Sampled.", &[("stage", "q\"w\\x\ny")], || 1.5);
        let h = r.histogram("mc_lat_ns", "Latency.", &[]);
        h.record(1000);
        let text = r.render();
        // One HELP + TYPE pair per family, in registration order.
        assert!(text.contains("# HELP mc_requests_total Requests served.\n"));
        assert!(text.contains("# TYPE mc_requests_total counter\n"));
        assert!(text.contains("mc_requests_total{shard=\"0\"} 7\n"));
        assert!(text.contains("# TYPE mc_depth gauge\n"));
        assert!(text.contains("mc_depth 42\n"));
        // Label escaping: backslash, quote, newline.
        assert!(
            text.contains("mc_rate{stage=\"q\\\"w\\\\x\\ny\"} 1.5\n"),
            "escaped label missing in:\n{text}"
        );
        // Histograms render as summaries: quantiles + _sum + _count.
        assert!(text.contains("# TYPE mc_lat_ns summary\n"));
        assert!(text.contains("mc_lat_ns{quantile=\"0.5\"} "));
        assert!(text.contains("mc_lat_ns{quantile=\"0.999\"} "));
        assert!(text.contains("mc_lat_ns_sum 1000\n"));
        assert!(text.contains("mc_lat_ns_count 1\n"));
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (head, value) = line.rsplit_once(' ').expect("sample line");
            assert!(!head.is_empty());
            assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
        }
    }

    #[test]
    #[should_panic(expected = "registered as")]
    fn kind_conflict_panics() {
        let r = Registry::new();
        let _ = r.counter("mc_thing", "h", &[]);
        let _ = r.gauge("mc_thing", "h", &[]);
    }

    #[test]
    fn concurrent_register_record_render() {
        use crate::sync::shim::{AtomicBool, Ordering};
        let r = Arc::new(Registry::new());
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for t in 0..4 {
            let r = Arc::clone(&r);
            handles.push(std::thread::spawn(move || {
                for i in 0..200 {
                    let shard = format!("{}", (t * 7 + i) % 5);
                    let c = r.counter("mc_conc_total", "h", &[("shard", &shard)]);
                    c.inc();
                    let h = r.histogram("mc_conc_ns", "h", &[("shard", &shard)]);
                    h.record(i as u64 + 1);
                }
            }));
        }
        {
            let r = Arc::clone(&r);
            let stop2 = Arc::clone(&stop);
            handles.push(std::thread::spawn(move || {
                let mut buf = String::new();
                while !stop2.load(Ordering::Relaxed) {
                    buf.clear();
                    r.render_into(&mut buf);
                }
            }));
        }
        for h in handles.drain(..4) {
            h.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
        // 4 threads x 200 increments spread over 5 shards.
        let total: u64 = (0..5)
            .map(|s| r.counter("mc_conc_total", "h", &[("shard", &format!("{s}"))]).get())
            .sum();
        assert_eq!(total, 800);
    }
}
