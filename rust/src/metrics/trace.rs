//! Structured query tracing: per-thread fixed-size span rings + a global
//! slow-query log (DESIGN.md §9).
//!
//! A *span* is one traced request (TOPK/MTOPK/REC) broken into stages
//! (`parse` → `infer` → `format`). Recording is allocation-free: a
//! [`SpanRecord`] is a fixed-size `Copy` struct written into a
//! preallocated ring slot; stage names are `&'static str`. Each thread
//! owns a ring (registered in a global list on first use, like the RCU
//! participant registry), so recording threads never contend with each
//! other — only a `TRACE dump` briefly locks each ring to copy it out.
//!
//! Two capture conditions, independently armed:
//!
//! * **Tracing on** (`TRACE on` wire verb): every span lands in its
//!   thread's ring (newest overwrite oldest).
//! * **Slow-query log** (`[server] slow_query_us`, 0 = off): any span
//!   whose total exceeds the threshold is *also* copied into a global
//!   ring that survives `TRACE off` — the flight recorder for tail
//!   latency. Slow capture works even while tracing is off.
//!
//! Both knobs are process-global atomics: a span costs one relaxed load
//! when nothing is armed, and the server only constructs [`Span`]s at
//! all when [`armed`] says so.

use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

use crate::sync::shim::{AtomicBool, AtomicU64, Ordering};

/// Spans kept per thread ring.
pub const RING_SPANS: usize = 256;
/// Spans kept in the global slow-query log.
pub const SLOW_SPANS: usize = 128;
/// Stage slots per span (excess stage marks are dropped, not grown).
pub const MAX_STAGES: usize = 6;
/// Inline bytes kept of a request's `id=` tag (longer tags truncate).
pub const MAX_ID_BYTES: usize = 16;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SLOW_US: AtomicU64 = AtomicU64::new(0);
/// Global finish-order sequence so `dump` can interleave rings.
static SEQ: AtomicU64 = AtomicU64::new(0);

/// One completed span: verb, subject, total, and per-stage nanoseconds.
#[derive(Debug, Clone, Copy)]
pub struct SpanRecord {
    /// Finish-order sequence number (process-global, monotonic).
    pub seq: u64,
    pub verb: &'static str,
    /// Src node of the query (first src for MTOPK).
    pub src: u64,
    /// `k` for top-k verbs; threshold-in-millionths for REC; batch size
    /// semantics are per-verb — it is a free detail slot.
    pub k: u64,
    pub total_ns: u64,
    /// True if this span exceeded the slow-query threshold.
    pub slow: bool,
    pub nstages: usize,
    pub stages: [(&'static str, u64); MAX_STAGES],
    /// Client request tag (`id=<token>` on the wire), truncated to the
    /// inline capacity — fixed bytes keep the record `Copy`.
    pub id: [u8; MAX_ID_BYTES],
    pub id_len: u8,
}

impl Default for SpanRecord {
    fn default() -> Self {
        SpanRecord {
            seq: 0,
            verb: "",
            src: 0,
            k: 0,
            total_ns: 0,
            slow: false,
            nstages: 0,
            stages: [("", 0); MAX_STAGES],
            id: [0; MAX_ID_BYTES],
            id_len: 0,
        }
    }
}

impl SpanRecord {
    /// The request tag as text ("" when the request was untagged).
    pub fn id_str(&self) -> &str {
        std::str::from_utf8(&self.id[..self.id_len as usize]).unwrap_or("")
    }
}

/// Fixed-capacity overwrite ring of spans.
struct Ring {
    slots: Vec<SpanRecord>,
    next: usize,
    len: usize,
    cap: usize,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring { slots: vec![SpanRecord::default(); cap], next: 0, len: 0, cap }
    }

    fn push(&mut self, rec: SpanRecord) {
        self.slots[self.next] = rec;
        self.next = (self.next + 1) % self.cap;
        self.len = (self.len + 1).min(self.cap);
    }

    fn copy_into(&self, out: &mut Vec<SpanRecord>) {
        out.extend(self.slots.iter().take(self.len).copied());
    }
}

fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Registry of every thread's ring. Rings are never removed (a few KB per
/// serving thread, bounded by the thread pool); a dead thread's ring just
/// stops receiving spans.
fn rings() -> &'static Mutex<Vec<std::sync::Arc<Mutex<Ring>>>> {
    static RINGS: OnceLock<Mutex<Vec<std::sync::Arc<Mutex<Ring>>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

fn slow_log() -> &'static Mutex<Ring> {
    static SLOW: OnceLock<Mutex<Ring>> = OnceLock::new();
    SLOW.get_or_init(|| Mutex::new(Ring::new(SLOW_SPANS)))
}

thread_local! {
    static MY_RING: std::sync::Arc<Mutex<Ring>> = {
        let ring = std::sync::Arc::new(Mutex::new(Ring::new(RING_SPANS)));
        lock_clean(rings()).push(std::sync::Arc::clone(&ring));
        ring
    };
}

/// Turn span capture into per-thread rings on/off (`TRACE on|off`).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Set the slow-query threshold in microseconds (0 disables the log).
pub fn set_slow_query_us(us: u64) {
    SLOW_US.store(us, Ordering::Relaxed);
}

pub fn slow_query_us() -> u64 {
    SLOW_US.load(Ordering::Relaxed)
}

/// Should the caller build a [`Span`] at all? One relaxed load each.
#[inline]
pub fn armed() -> bool {
    enabled() || slow_query_us() > 0
}

/// An in-flight span. Build with [`Span::start`], mark stage boundaries
/// with [`Span::stage`], commit with [`Span::finish`]. Stack-only.
pub struct Span {
    rec: SpanRecord,
    start: Instant,
    mark: Instant,
}

impl Span {
    pub fn start(verb: &'static str, src: u64, k: u64) -> Span {
        Self::start_at(verb, src, k, Instant::now())
    }

    /// Start a span back-dated to `started` — for callers that measured a
    /// leading stage (request parsing) before they knew the verb and so
    /// could not construct the span yet.
    pub fn start_at(verb: &'static str, src: u64, k: u64, started: Instant) -> Span {
        Span {
            rec: SpanRecord { verb, src, k, ..SpanRecord::default() },
            start: started,
            mark: started,
        }
    }

    /// Stamp the client's request tag onto the span (truncated to
    /// [`MAX_ID_BYTES`] on a character boundary).
    pub fn set_id(&mut self, tag: &str) {
        let mut end = tag.len().min(MAX_ID_BYTES);
        while end > 0 && !tag.is_char_boundary(end) {
            end -= 1;
        }
        self.rec.id[..end].copy_from_slice(&tag.as_bytes()[..end]);
        self.rec.id_len = end as u8;
    }

    /// Close the current stage: everything since the previous mark (or
    /// the span start) is attributed to `name`.
    pub fn stage(&mut self, name: &'static str) {
        let now = Instant::now();
        if self.rec.nstages < MAX_STAGES {
            self.rec.stages[self.rec.nstages] =
                (name, now.duration_since(self.mark).as_nanos() as u64);
            self.rec.nstages += 1;
        }
        self.mark = now;
    }

    /// Commit the span: into this thread's ring when tracing is on, and
    /// into the slow log when it beat the threshold.
    pub fn finish(mut self) {
        self.rec.total_ns = self.start.elapsed().as_nanos() as u64;
        let slow_us = slow_query_us();
        self.rec.slow = slow_us > 0 && self.rec.total_ns >= slow_us.saturating_mul(1000);
        if !self.rec.slow && !enabled() {
            return;
        }
        self.rec.seq = SEQ.fetch_add(1, Ordering::Relaxed) + 1;
        if self.rec.slow {
            lock_clean(slow_log()).push(self.rec);
        }
        if enabled() {
            MY_RING.with(|r| lock_clean(r).push(self.rec));
        }
    }
}

/// Drop a synthetic zero-duration marker straight into the slow-query
/// flight recorder, regardless of the armed knobs — for events that must
/// be visible in the next `TRACE dump` (invariant violations, audit
/// escalations). `src`/`k` carry verb-specific detail, like on a span.
pub fn record_mark(verb: &'static str, src: u64, k: u64) {
    let rec = SpanRecord {
        seq: SEQ.fetch_add(1, Ordering::Relaxed) + 1,
        verb,
        src,
        k,
        slow: true,
        ..SpanRecord::default()
    };
    lock_clean(slow_log()).push(rec);
}

/// The most recent `n` captured spans (slow log + every thread ring),
/// newest first by finish order.
pub fn dump(n: usize) -> Vec<SpanRecord> {
    let mut out = Vec::new();
    lock_clean(slow_log()).copy_into(&mut out);
    for ring in lock_clean(rings()).iter() {
        lock_clean(ring).copy_into(&mut out);
    }
    out.sort_unstable_by(|a, b| b.seq.cmp(&a.seq));
    // A span can sit in both its thread ring and the slow log.
    out.dedup_by_key(|r| r.seq);
    out.truncate(n);
    out
}

/// Serialize tests that touch the process-global capture state (the
/// knobs, rings, and slow log are shared by every test thread).
#[cfg(test)]
pub(crate) fn test_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    lock_clean(LOCK.get_or_init(|| Mutex::new(())))
}

/// Reset capture state (tests share the process-global rings).
pub fn reset() {
    set_enabled(false);
    set_slow_query_us(0);
    for ring in lock_clean(rings()).iter() {
        let mut r = lock_clean(ring);
        r.len = 0;
        r.next = 0;
    }
    let mut s = lock_clean(slow_log());
    s.len = 0;
    s.next = 0;
}

#[cfg(test)]
mod tests {
    use super::*;

    // The rings/knobs are process-global: every assertion about capture
    // volume lives in this one test so parallel test threads cannot race
    // the shared state.
    #[test]
    fn spans_stages_slow_log_and_dump() {
        let _guard = test_lock();
        reset();
        assert!(!armed());

        // Tracing off + no slow threshold: finish is a no-op.
        let s = Span::start("TOPK", 1, 8);
        s.finish();
        assert!(dump(10).is_empty());

        // Tracing on: spans land in the thread ring with stage splits.
        set_enabled(true);
        let mut s = Span::start("TOPK", 7, 8);
        s.stage("parse");
        std::thread::sleep(std::time::Duration::from_micros(200));
        s.stage("infer");
        s.stage("format");
        s.finish();
        let spans = dump(10);
        assert_eq!(spans.len(), 1);
        let r = &spans[0];
        assert_eq!(r.verb, "TOPK");
        assert_eq!(r.src, 7);
        assert_eq!(r.nstages, 3);
        assert_eq!(r.stages[1].0, "infer");
        assert!(r.stages[1].1 >= 100_000, "infer stage {}ns", r.stages[1].1);
        assert!(r.total_ns >= r.stages.iter().take(3).map(|s| s.1).sum::<u64>());
        assert!(!r.slow);

        // Slow log captures past-threshold spans even with tracing OFF.
        set_enabled(false);
        set_slow_query_us(50); // 50 µs
        let mut s = Span::start("MTOPK", 3, 4);
        s.stage("parse");
        std::thread::sleep(std::time::Duration::from_micros(300));
        s.stage("infer");
        s.finish();
        let spans = dump(10);
        assert_eq!(spans.len(), 2, "slow span + earlier traced span");
        assert!(spans[0].slow, "newest span must be the slow one");
        assert_eq!(spans[0].verb, "MTOPK");

        // A fast span under the threshold with tracing off: dropped.
        let s = Span::start("TOPK", 9, 1);
        s.finish();
        assert_eq!(dump(10).len(), 2);

        // dump(n) truncates newest-first.
        assert_eq!(dump(1).len(), 1);
        assert_eq!(dump(1)[0].verb, "MTOPK");

        reset();
        assert!(dump(10).is_empty());
    }

    #[test]
    fn id_tags_and_marks_reach_the_flight_recorder() {
        let _guard = test_lock();
        reset();
        // A mark lands in the slow log with nothing armed at all.
        record_mark("AUDIT", 3, 0);
        let spans = dump(10);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].verb, "AUDIT");
        assert_eq!(spans[0].src, 3);
        assert!(spans[0].slow);
        assert_eq!(spans[0].id_str(), "");

        // set_id round-trips and truncates on a char boundary.
        set_enabled(true);
        let mut s = Span::start("TOPK", 1, 2);
        s.set_id("req-42");
        s.finish();
        assert_eq!(dump(1)[0].id_str(), "req-42");
        let mut s = Span::start("TOPK", 1, 2);
        s.set_id("0123456789abcdefOVERFLOW");
        s.finish();
        assert_eq!(dump(1)[0].id_str(), "0123456789abcdef");
        let mut s = Span::start("TOPK", 1, 2);
        s.set_id("0123456789abcdeé"); // é straddles the 16-byte cut
        s.finish();
        assert_eq!(dump(1)[0].id_str(), "0123456789abcde");
        reset();
    }

    #[test]
    fn ring_overwrites_at_capacity() {
        let mut r = Ring::new(4);
        for i in 0..10u64 {
            r.push(SpanRecord { seq: i, ..SpanRecord::default() });
        }
        assert_eq!(r.len, 4);
        let mut out = Vec::new();
        r.copy_into(&mut out);
        let mut seqs: Vec<u64> = out.iter().map(|s| s.seq).collect();
        seqs.sort_unstable();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
    }
}
