//! Structured event log (DESIGN.md §10): a bounded in-process ring of
//! timestamped, leveled, `Copy` event records — health transitions,
//! quarantine/heal, promotion, checkpoint/compaction, chaos injections,
//! audit violations. The ring is the system's black box: when a scrape
//! shows `mcprioq_invariant_violations_total` ticking, `EVENTS` (wire)
//! or `GET /events` (sidecar) answers *what happened around then* without
//! grepping logs.
//!
//! Design mirrors [`super::trace`]: fixed-capacity ring, `Copy` records
//! with `&'static str` identity (no allocation on the emit path beyond
//! the one-time ring), newest-first dumps, and poisoning-tolerant locks.
//! Unlike trace spans, events are rare (transitions, not requests), so a
//! single process-wide ring behind a mutex is cheap — emit is a lock,
//! two stores, and a timestamp, and it is called on paths that already
//! do I/O or take maintenance locks.

use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::sync::shim::{AtomicU64, Ordering};

/// Ring capacity: enough for hours of transition-rate events; a chaos
/// run emitting one event per injected fault stays well inside it.
const RING_EVENTS: usize = 1024;

/// Severity of an event. `Warn` marks degradations the system absorbs
/// (quarantine, shed bursts, chaos injections); `Error` marks contract
/// breaches (invariant violations, replication faults).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    Info,
    Warn,
    Error,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }
}

/// One event. `Copy` + `'static` identity so records move through the
/// ring and out of dumps without allocation. `kind` names the subsystem
/// edge ("health", "checkpoint", "promotion", "audit", "chaos", ...),
/// `what` the specific transition or check; `a`/`b` carry two
/// kind-specific integers (documented per emitter — e.g. checkpoint
/// generation + bytes, violation count + shard).
#[derive(Clone, Copy, Debug)]
pub struct EventRecord {
    /// Global emit order (1-based); later seq = later event.
    pub seq: u64,
    /// Milliseconds since process start (monotonic clock, not wall time:
    /// events correlate with each other and with uptime, not calendars).
    pub ts_ms: u64,
    pub level: Level,
    pub kind: &'static str,
    pub what: &'static str,
    pub a: u64,
    pub b: u64,
}

impl Default for EventRecord {
    fn default() -> Self {
        EventRecord { seq: 0, ts_ms: 0, level: Level::Info, kind: "", what: "", a: 0, b: 0 }
    }
}

/// Fixed-capacity overwrite ring (same shape as the trace ring): `next`
/// is the write cursor, `len` saturates at capacity.
struct Ring {
    slots: Vec<EventRecord>,
    next: usize,
    len: usize,
    cap: usize,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring { slots: vec![EventRecord::default(); cap], next: 0, len: 0, cap }
    }

    fn push(&mut self, rec: EventRecord) {
        self.slots[self.next] = rec;
        self.next = (self.next + 1) % self.cap;
        self.len = (self.len + 1).min(self.cap);
    }

    /// Append the newest `n` records into `out`, newest first.
    fn copy_newest(&self, n: usize, out: &mut Vec<EventRecord>) {
        let take = n.min(self.len);
        for i in 0..take {
            // next-1 is the newest slot; walk backwards with wraparound.
            let idx = (self.next + self.cap - 1 - i) % self.cap;
            out.push(self.slots[idx]);
        }
    }
}

fn ring() -> &'static Mutex<Ring> {
    static RING: OnceLock<Mutex<Ring>> = OnceLock::new();
    RING.get_or_init(|| Mutex::new(Ring::new(RING_EVENTS)))
}

/// A panicking emitter must not wedge the event log for everyone else;
/// records are `Copy`, so a poisoned ring is still structurally sound.
fn lock_clean(m: &Mutex<Ring>) -> MutexGuard<'_, Ring> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

static SEQ: AtomicU64 = AtomicU64::new(0);

/// Record one event. Cheap enough for any transition path (one short
/// critical section, no allocation), but not meant for per-request use —
/// that is what trace spans are for.
pub fn emit(level: Level, kind: &'static str, what: &'static str, a: u64, b: u64) {
    let rec = EventRecord {
        seq: SEQ.fetch_add(1, Ordering::Relaxed) + 1,
        ts_ms: epoch().elapsed().as_millis() as u64,
        level,
        kind,
        what,
        a,
        b,
    };
    lock_clean(ring()).push(rec);
}

/// Total events emitted since process start (monotone; feeds the
/// `mcprioq_events_emitted_total` registry counter).
pub fn emitted() -> u64 {
    SEQ.load(Ordering::Relaxed)
}

/// The newest `n` events, newest first.
pub fn dump(n: usize) -> Vec<EventRecord> {
    let mut out = Vec::new();
    lock_clean(ring()).copy_newest(n, &mut out);
    out
}

/// Render one record in the event grammar (DESIGN.md §10):
/// `ts_ms=<u64> seq=<u64> level=<info|warn|error> kind=<word> what=<word> a=<u64> b=<u64>`.
pub fn render_record(out: &mut String, r: &EventRecord) {
    use std::fmt::Write as _;
    let _ = write!(
        out,
        "ts_ms={} seq={} level={} kind={} what={} a={} b={}",
        r.ts_ms,
        r.seq,
        r.level.as_str(),
        r.kind,
        r.what,
        r.a,
        r.b
    );
}

/// Render the newest `n` events one-per-line, newest first — the body of
/// the sidecar's `GET /events`.
pub fn render_text(out: &mut String, n: usize) {
    for r in dump(n) {
        render_record(out, &r);
        out.push('\n');
    }
}

/// Drop all buffered events (tests; the seq counter keeps running so
/// ordering stays globally monotone across a reset).
pub fn reset() {
    let mut g = lock_clean(ring());
    g.next = 0;
    g.len = 0;
}

/// Serializes tests that share the process-wide ring.
#[cfg(test)]
pub(crate) fn test_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emit_and_dump_newest_first() {
        let _g = test_lock();
        reset();
        emit(Level::Info, "health", "healthy->degraded", 1, 0);
        emit(Level::Warn, "chaos", "enospc", 2, 0);
        emit(Level::Error, "audit", "cum_monotone", 3, 7);
        let got = dump(10);
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].kind, "audit");
        assert_eq!(got[0].what, "cum_monotone");
        assert_eq!(got[0].a, 3);
        assert_eq!(got[0].b, 7);
        assert_eq!(got[1].kind, "chaos");
        assert_eq!(got[2].kind, "health");
        assert!(got[0].seq > got[1].seq && got[1].seq > got[2].seq);
    }

    #[test]
    fn dump_respects_n_and_ring_wraps() {
        let _g = test_lock();
        reset();
        for i in 0..(RING_EVENTS as u64 + 10) {
            emit(Level::Info, "fill", "wrap", i, 0);
        }
        let newest = dump(2);
        assert_eq!(newest.len(), 2);
        assert_eq!(newest[0].a, RING_EVENTS as u64 + 9);
        assert_eq!(newest[1].a, RING_EVENTS as u64 + 8);
        // Saturated: a full dump returns exactly the capacity, and the
        // oldest surviving record is capacity slots behind the newest.
        let all = dump(usize::MAX);
        assert_eq!(all.len(), RING_EVENTS);
        assert_eq!(all.last().unwrap().a, 10);
    }

    #[test]
    fn render_grammar_round_trips_fields() {
        let _g = test_lock();
        reset();
        emit(Level::Warn, "checkpoint", "commit", 4, 4096);
        let mut s = String::new();
        render_text(&mut s, 1);
        assert!(s.contains("level=warn"), "{s}");
        assert!(s.contains("kind=checkpoint"), "{s}");
        assert!(s.contains("what=commit"), "{s}");
        assert!(s.contains("a=4 b=4096"), "{s}");
        assert!(s.ends_with('\n'));
    }
}
