//! Log-bucketed concurrent histogram (HdrHistogram-lite): 2.5%-precision
//! buckets over the full u64 range, lock-free recording, mergeable.

use crate::sync::shim::{AtomicU64, Ordering};

/// Sub-buckets per power of two (higher = finer percentiles).
const SUB_BITS: u32 = 5;
const SUB: usize = 1 << SUB_BITS; // 32
/// 64 exponents x 32 sub-buckets.
const BUCKETS: usize = 64 * SUB;

pub struct Histogram {
    buckets: Box<[AtomicU64; BUCKETS]>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

/// Point-in-time summary of a histogram.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Snapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    pub mean: f64,
    pub p50: u64,
    pub p90: u64,
    pub p99: u64,
    pub p999: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        // Box<[AtomicU64; N]> without transmute gymnastics: vec -> try_into.
        let v: Vec<AtomicU64> = (0..BUCKETS).map(|_| AtomicU64::new(0)).collect();
        let buckets: Box<[AtomicU64; BUCKETS]> = v.into_boxed_slice().try_into().ok().unwrap();
        Histogram {
            buckets,
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    fn index(value: u64) -> usize {
        let v = value.max(1);
        let exp = 63 - v.leading_zeros() as usize;
        if exp < SUB_BITS as usize {
            // Values below 2^SUB_BITS map 1:1.
            return v as usize;
        }
        let sub = ((v >> (exp - SUB_BITS as usize)) as usize) & (SUB - 1);
        (exp << SUB_BITS) as usize + sub
    }

    /// Representative (upper-bound) value of a bucket. Total over every
    /// index: monotonic non-decreasing and panic-free across the full
    /// range, including the top exponents (the old `(sub+1) << exp >>
    /// SUB_BITS` overflowed the up-shift for `exp > 63 - SUB_BITS`,
    /// wrapping p999 of histograms holding values near `u64::MAX`).
    fn bucket_value(idx: usize) -> u64 {
        if idx < SUB {
            // Values below 2^SUB_BITS map 1:1 in `index`.
            return idx as u64;
        }
        let exp = idx >> SUB_BITS;
        let sub = (idx & (SUB - 1)) as u64;
        if exp < SUB_BITS as usize {
            // Dead zone: `index` never produces these slots (small values
            // take the 1:1 branch above; values >= SUB land at exp >=
            // SUB_BITS). Clamp to the 1:1 region's ceiling so a sweep
            // over all indices stays monotonic.
            return (SUB - 1) as u64;
        }
        // exp <= 63 because idx < BUCKETS = 64 * SUB. Shifting the sub
        // offset by `exp - SUB_BITS` directly (instead of up by `exp`
        // then down by SUB_BITS) keeps every intermediate in range:
        // (sub+1) <= 2^SUB_BITS, so the shift tops out at 2^63.
        let base = 1u64 << exp;
        base.saturating_add(((sub + 1) << (exp - SUB_BITS as usize)) - 1)
    }

    #[inline]
    pub fn record(&self, value: u64) {
        self.buckets[Self::index(value)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.min.fetch_min(value, Ordering::Relaxed);
        self.max.fetch_max(value, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn merge_from(&self, other: &Histogram) {
        for i in 0..BUCKETS {
            let v = other.buckets[i].load(Ordering::Relaxed);
            if v > 0 {
                self.buckets[i].fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min.fetch_min(other.min.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    pub fn percentile(&self, p: f64) -> u64 {
        let total = self.count.load(Ordering::Relaxed);
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for i in 0..BUCKETS {
            acc += self.buckets[i].load(Ordering::Relaxed);
            if acc >= target {
                return Self::bucket_value(i).min(self.max.load(Ordering::Relaxed));
            }
        }
        self.max.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> Snapshot {
        let count = self.count.load(Ordering::Relaxed);
        let sum = self.sum.load(Ordering::Relaxed);
        Snapshot {
            count,
            sum,
            min: if count == 0 { 0 } else { self.min.load(Ordering::Relaxed) },
            max: self.max.load(Ordering::Relaxed),
            mean: if count == 0 { 0.0 } else { sum as f64 / count as f64 },
            p50: self.percentile(50.0),
            p90: self.percentile(90.0),
            p99: self.percentile(99.0),
            p999: self.percentile(99.9),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot_is_zero() {
        let h = Histogram::new();
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, 0);
        assert_eq!(s.min, 0);
    }

    #[test]
    fn single_value() {
        let h = Histogram::new();
        h.record(1000);
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        assert_eq!(s.min, 1000);
        assert_eq!(s.max, 1000);
        assert_eq!(s.mean, 1000.0);
        // Bucketed percentile within 2x of the true value (log buckets).
        assert!(s.p50 >= 1000 && s.p50 <= 1064, "p50 {}", s.p50);
    }

    #[test]
    fn percentiles_of_uniform_range() {
        let h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 10_000);
        // Log-bucket precision: within ~4% of truth.
        assert!((s.p50 as f64 - 5_000.0).abs() / 5_000.0 < 0.05, "p50 {}", s.p50);
        assert!((s.p90 as f64 - 9_000.0).abs() / 9_000.0 < 0.05, "p90 {}", s.p90);
        assert!((s.p99 as f64 - 9_900.0).abs() / 9_900.0 < 0.05, "p99 {}", s.p99);
        assert_eq!(s.max, 10_000);
        assert_eq!(s.min, 1);
    }

    #[test]
    fn small_values_exact() {
        let h = Histogram::new();
        for v in [0u64, 1, 2, 3, 3, 3] {
            h.record(v);
        }
        assert_eq!(h.percentile(100.0), 3);
        assert_eq!(h.snapshot().min, 0);
    }

    #[test]
    fn merge_combines() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(10);
        b.record(1_000_000);
        a.merge_from(&b);
        let s = a.snapshot();
        assert_eq!(s.count, 2);
        assert_eq!(s.min, 10);
        assert_eq!(s.max, 1_000_000);
    }

    #[test]
    fn concurrent_recording() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::new());
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(t * 1000 + i % 100);
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
    }

    #[test]
    fn huge_values_do_not_panic() {
        let h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX / 2);
        assert_eq!(h.snapshot().max, u64::MAX);
    }

    #[test]
    fn bucket_values_monotonic_and_panic_free_over_every_index() {
        let mut prev = 0u64;
        for idx in 0..BUCKETS {
            let v = Histogram::bucket_value(idx);
            assert!(
                v >= prev,
                "bucket_value({idx}) = {v} < bucket_value({}) = {prev}",
                idx.saturating_sub(1)
            );
            prev = v;
        }
        // The top bucket's representative is the saturated ceiling — the
        // old shift-then-correct order wrapped here instead.
        assert_eq!(Histogram::bucket_value(BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn bucket_value_is_an_upper_bound_of_its_bucket() {
        // The representative of a value's bucket must never undershoot
        // the value (that is what makes `percentile` an upper estimate).
        // Sweep powers of two +-1 across the whole u64 range, including
        // the exponents where the old formula overflowed.
        for e in 0..64u32 {
            for v in [1u64 << e, (1u64 << e).saturating_add(1), (1u64 << e).saturating_sub(1)] {
                if v == 0 {
                    continue;
                }
                let rep = Histogram::bucket_value(Histogram::index(v));
                assert!(rep >= v, "bucket_value(index({v})) = {rep} < {v}");
            }
        }
        assert!(Histogram::bucket_value(Histogram::index(u64::MAX)) >= u64::MAX / 2);
    }
}
