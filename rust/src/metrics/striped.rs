//! Thread-striped counter: `add` touches a per-thread-striped cache line
//! instead of one global line, so hot-path accounting never serializes
//! writers (perf-pass finding, EXPERIMENTS.md §Perf).

use crate::sync::shim::{AtomicU64, AtomicUsize, Ordering};
use crate::sync::CachePadded;

const STRIPES: usize = 16;

static NEXT_STRIPE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Each thread gets a home stripe round-robin at first use.
    static STRIPE: usize = NEXT_STRIPE.fetch_add(1, Ordering::Relaxed) % STRIPES;
}

#[derive(Default)]
pub struct StripedCounter {
    stripes: [CachePadded<AtomicU64>; STRIPES],
}

impl StripedCounter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&self, n: u64) {
        let s = STRIPE.with(|s| *s);
        self.stripes[s].fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.stripes.iter().map(|s| s.load(Ordering::Relaxed)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counts_across_threads() {
        let c = Arc::new(StripedCounter::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn add_batches() {
        let c = StripedCounter::new();
        c.add(5);
        c.add(7);
        assert_eq!(c.get(), 12);
    }
}
