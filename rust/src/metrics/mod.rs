//! Metrics substrate: counters, log-bucketed histograms, latency/throughput
//! recorders. Lock-free recording (atomics only) so metrics can sit on the
//! serving hot path.

pub mod events;
mod histogram;
mod perf_counters;
pub mod registry;
mod striped;
pub mod trace;

pub use histogram::{Histogram, Snapshot};
pub use perf_counters::{PerfCounters, PerfSample};
pub use registry::Registry;
pub use striped::StripedCounter;

use std::time::Instant;

use crate::sync::shim::{AtomicBool, AtomicU64, Ordering};

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous gauge (set/get).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Self {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    #[inline]
    pub fn max_update(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }
}

/// Times a scope and records nanoseconds into a histogram on drop.
pub struct Timer<'a> {
    hist: &'a Histogram,
    start: Instant,
}

impl<'a> Timer<'a> {
    pub fn start(hist: &'a Histogram) -> Self {
        Timer { hist, start: Instant::now() }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        self.hist.record(self.start.elapsed().as_nanos() as u64);
    }
}

/// Windowed throughput meter: count events, read events/sec since the last
/// `rate()` call.
pub struct Meter {
    count: AtomicU64,
    last_count: AtomicU64,
    last_at_nanos: AtomicU64,
    /// Seqlock-style writer guard over the `(last_count, last_at_nanos)`
    /// window pair: exactly one `rate()` caller advances the window at a
    /// time, so the pair is always a consistent unit and a concurrent
    /// reader can never pair a new count with an old timestamp (the old
    /// two-independent-swaps scheme could, yielding windows that only
    /// `saturating_sub` kept from going negative).
    window_lock: AtomicBool,
    epoch: Instant,
}

impl Default for Meter {
    fn default() -> Self {
        Self::new()
    }
}

impl Meter {
    pub fn new() -> Self {
        Meter {
            count: AtomicU64::new(0),
            last_count: AtomicU64::new(0),
            last_at_nanos: AtomicU64::new(0),
            window_lock: AtomicBool::new(false),
            epoch: Instant::now(),
        }
    }

    #[inline]
    pub fn mark(&self) {
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn mark_n(&self, n: u64) {
        self.count.fetch_add(n, Ordering::Relaxed);
    }

    pub fn total(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Events/sec since the previous `rate()` call (or since creation).
    ///
    /// The window is shared: every caller advances it, and the
    /// `window_lock` guard serializes the advance so `(last_count,
    /// last_at_nanos)` is exchanged as one unit — concurrent callers each
    /// get a consistent (possibly tiny) window instead of pairing another
    /// caller's count with their own timestamp. Off the hot path: only
    /// STATS/exposition readers ever contend here.
    pub fn rate(&self) -> f64 {
        while self.window_lock.swap(true, Ordering::Acquire) {
            crate::sync::shim::hint::spin_loop();
        }
        let now = self.epoch.elapsed().as_nanos() as u64;
        let cur = self.count.load(Ordering::Relaxed);
        let prev_t = self.last_at_nanos.swap(now, Ordering::Relaxed);
        let prev_c = self.last_count.swap(cur, Ordering::Relaxed);
        self.window_lock.store(false, Ordering::Release);
        // Inside the guard `cur` was read after the previous window's
        // store, and the counter is monotonic, so `cur >= prev_c` and
        // `now >= prev_t` always hold; the saturations are now belt and
        // braces rather than load-bearing.
        let dt = now.saturating_sub(prev_t) as f64 / 1e9;
        if dt <= 0.0 {
            return 0.0;
        }
        cur.saturating_sub(prev_c) as f64 / dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(10);
        g.max_update(7);
        assert_eq!(g.get(), 10);
        g.max_update(12);
        assert_eq!(g.get(), 12);
    }

    #[test]
    fn timer_records() {
        let h = Histogram::new();
        {
            let _t = Timer::start(&h);
            std::hint::black_box(0);
        }
        assert_eq!(h.snapshot().count, 1);
    }

    #[test]
    fn meter_rate_concurrent_windows_stay_sane() {
        use std::sync::Arc;
        let m = Arc::new(Meter::new());
        let mut handles = Vec::new();
        // Writers keep the counter moving while many readers race the
        // shared window. Before the window guard, interleaved swaps could
        // pair a fresh count with a stale timestamp (or vice versa) and
        // produce saturated-to-zero deltas over large dt — i.e. windows
        // that had gone "negative". Every observed rate must be finite,
        // non-negative, and physically possible.
        for _ in 0..2 {
            let m = Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for _ in 0..50_000 {
                    m.mark();
                }
            }));
        }
        for _ in 0..4 {
            let m = Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for _ in 0..2_000 {
                    let r = m.rate();
                    assert!(r.is_finite(), "rate {r}");
                    assert!(r >= 0.0, "negative-saturated window: {r}");
                    // 100k events over a >= 1ns window bounds the rate at
                    // 1e14/s; anything above means a wrapped delta.
                    assert!(r <= 1e14, "impossible rate {r}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.total(), 100_000);
    }

    #[test]
    fn meter_counts_and_rates() {
        let m = Meter::new();
        m.mark_n(100);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let r = m.rate();
        assert!(r > 0.0);
        assert_eq!(m.total(), 100);
        // Second window with no events.
        let r2 = m.rate();
        assert_eq!(r2, 0.0);
    }
}
