//! The synchronization facade: the single place the crate is allowed to
//! import atomics, low-level interior mutability, and blocking primitives
//! from (enforced by `tools/unsafe_audit.py` in CI).
//!
//! Normally everything re-exports `std`, so the facade is zero-cost. Under
//! `RUSTFLAGS="--cfg loom"` the same names resolve to the vendored loom
//! model checker (`vendor/loom`), which turns every operation into a
//! scheduling point with vector-clock race checking — the protocol models
//! in `rust/tests/loom_models.rs` run the *production* code paths through
//! it. See DESIGN.md §"Concurrency verification".
//!
//! Import rules for the rest of the crate:
//!
//! - atomics, `Ordering`, `fence`: `use crate::sync::shim::{...}`;
//! - interior mutability behind a lock/protocol: [`UnsafeCell`] (closure
//!   API, so loom can record exactly when each access happens);
//! - blocking used by modeled code (ingest queue, RCU bags):
//!   [`Mutex`]/[`Condvar`];
//! - spin hints and yields inside retry loops: [`hint::spin_loop`] /
//!   [`thread::yield_now`] — under loom these deschedule, which is what
//!   lets a model containing a spin loop terminate.

#[cfg(not(loom))]
pub use std::sync::atomic::{
    fence, AtomicBool, AtomicPtr, AtomicU64, AtomicU8, AtomicUsize, Ordering,
};

#[cfg(loom)]
pub use loom::sync::atomic::{
    fence, AtomicBool, AtomicPtr, AtomicU64, AtomicU8, AtomicUsize, Ordering,
};

#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

#[cfg(loom)]
pub use loom::cell::UnsafeCell;

/// `std` twin of `loom::cell::UnsafeCell`: same closure-scoped API (loom
/// needs the closure to know exactly when the access happens; the `std`
/// build inlines to a plain pointer access).
#[cfg(not(loom))]
#[derive(Debug, Default)]
#[repr(transparent)]
pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

#[cfg(not(loom))]
impl<T> UnsafeCell<T> {
    pub const fn new(v: T) -> Self {
        UnsafeCell(std::cell::UnsafeCell::new(v))
    }

    /// Shared access. The pointer must not outlive the closure, and the
    /// caller upholds the usual aliasing rules when dereferencing it.
    #[inline(always)]
    pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
        f(self.0.get())
    }

    /// Exclusive access; same contract as [`Self::with`], plus the caller
    /// guarantees no concurrent access for the closure's duration.
    #[inline(always)]
    pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        f(self.0.get())
    }

    pub fn get_mut(&mut self) -> &mut T {
        self.0.get_mut()
    }

    pub fn into_inner(self) -> T {
        self.0.into_inner()
    }
}

pub mod hint {
    #[cfg(not(loom))]
    pub use std::hint::spin_loop;

    #[cfg(loom)]
    pub use loom::hint::spin_loop;
}

pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::{spawn, yield_now, JoinHandle};

    #[cfg(loom)]
    pub use loom::thread::{spawn, yield_now, JoinHandle};
}
