//! Exponential backoff for CAS retry loops (crossbeam-style).

use super::shim;

const SPIN_LIMIT: u32 = 6;
const YIELD_LIMIT: u32 = 10;

/// Exponential backoff: spin a few rounds, then start yielding the CPU.
pub struct Backoff {
    step: u32,
}

impl Backoff {
    pub fn new() -> Self {
        Backoff { step: 0 }
    }

    /// Back off after a failed CAS in a lock-free loop (spin only).
    pub fn spin(&mut self) {
        // Under loom every spin hint is a scheduling point; one is enough
        // (more would only burn the model's op budget).
        #[cfg(loom)]
        shim::hint::spin_loop();
        #[cfg(not(loom))]
        for _ in 0..1u32 << self.step.min(SPIN_LIMIT) {
            shim::hint::spin_loop();
        }
        if self.step <= SPIN_LIMIT {
            self.step += 1;
        }
    }

    /// Back off while waiting for another thread to make progress
    /// (spin, then yield to the scheduler).
    pub fn snooze(&mut self) {
        #[cfg(loom)]
        shim::thread::yield_now();
        #[cfg(not(loom))]
        if self.step <= SPIN_LIMIT {
            for _ in 0..1u32 << self.step {
                shim::hint::spin_loop();
            }
        } else {
            shim::thread::yield_now();
        }
        if self.step <= YIELD_LIMIT {
            self.step += 1;
        }
    }

    /// True once spinning stopped helping and the caller should consider
    /// parking or restructuring.
    pub fn is_completed(&self) -> bool {
        self.step > YIELD_LIMIT
    }
}

impl Default for Backoff {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_saturates() {
        let mut b = Backoff::new();
        for _ in 0..64 {
            b.spin();
        }
        assert!(b.step >= SPIN_LIMIT);
        let mut b = Backoff::new();
        for _ in 0..64 {
            b.snooze();
        }
        assert!(b.is_completed());
    }
}
