//! Low-level concurrency utilities shared across the crate (no external
//! crates available offline — these replace `crossbeam_utils` equivalents).

mod backoff;
pub mod shim;
mod spinlock;

pub use backoff::Backoff;
pub use spinlock::{SpinLock, SpinLockGuard};

use std::ops::{Deref, DerefMut};

/// Pads and aligns a value to 128 bytes (two x86-64 cache lines — the
/// spatial-prefetcher granule) to prevent false sharing between adjacent
/// hot atomics such as the per-edge and per-node counters (§II.3).
#[derive(Default)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    pub const fn new(value: T) -> Self {
        CachePadded { value }
    }

    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> Deref for CachePadded<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for CachePadded<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.value.fmt(f)
    }
}

#[cfg(test)]
mod tests {
    use super::shim::{AtomicU64, Ordering};
    use super::*;

    #[test]
    fn cache_padded_is_aligned() {
        assert_eq!(std::mem::align_of::<CachePadded<AtomicU64>>(), 128);
        assert!(std::mem::size_of::<CachePadded<AtomicU64>>() >= 128);
    }

    #[test]
    fn cache_padded_derefs() {
        let c = CachePadded::new(AtomicU64::new(7));
        c.fetch_add(1, Ordering::Relaxed);
        assert_eq!(c.load(Ordering::Relaxed), 8);
        assert_eq!(CachePadded::new(3u32).into_inner(), 3);
    }
}
