//! A tiny test-and-test-and-set spinlock.
//!
//! Used ONLY on cold paths (new-edge hash insert, table resize, decay
//! bookkeeping) — never on the read or increment hot paths, which stay
//! wait-free. See DESIGN.md §2 for where locking is and is not permitted.

use std::ops::{Deref, DerefMut};

use super::shim::{AtomicBool, Ordering, UnsafeCell};
use super::Backoff;

pub struct SpinLock<T> {
    locked: AtomicBool,
    value: UnsafeCell<T>,
}

// SAFETY: the lock serializes every access to `value`, so moving or
// sharing the SpinLock only ever hands the inner `T` to one thread at a
// time — `T: Send` is exactly the bound that permits (same as std Mutex;
// `T: Sync` is not required because no two threads view the T at once).
unsafe impl<T: Send> Send for SpinLock<T> {}
// SAFETY: see the `Send` justification above.
unsafe impl<T: Send> Sync for SpinLock<T> {}

impl<T> SpinLock<T> {
    pub const fn new(value: T) -> Self {
        SpinLock { locked: AtomicBool::new(false), value: UnsafeCell::new(value) }
    }

    pub fn lock(&self) -> SpinLockGuard<'_, T> {
        let mut backoff = Backoff::new();
        loop {
            // Test-and-test-and-set: spin on a read before attempting the
            // exclusive CAS to avoid cache-line ping-pong.
            while self.locked.load(Ordering::Relaxed) {
                backoff.snooze();
            }
            if self
                .locked
                .compare_exchange_weak(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
            {
                return SpinLockGuard { lock: self };
            }
            backoff.spin();
        }
    }

    pub fn try_lock(&self) -> Option<SpinLockGuard<'_, T>> {
        if !self.locked.load(Ordering::Relaxed)
            && self
                .locked
                .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
        {
            Some(SpinLockGuard { lock: self })
        } else {
            None
        }
    }

    pub fn is_locked(&self) -> bool {
        self.locked.load(Ordering::Relaxed)
    }

    pub fn get_mut(&mut self) -> &mut T {
        self.value.get_mut()
    }
}

pub struct SpinLockGuard<'a, T> {
    lock: &'a SpinLock<T>,
}

impl<T> Deref for SpinLockGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // SAFETY: the guard holds the lock, so no mutable access exists;
        // the reference cannot outlive the guard (and thus the lock). Under
        // loom the `with` records a read access for race checking.
        self.lock.value.with(|p| unsafe { &*p })
    }
}

impl<T> DerefMut for SpinLockGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // SAFETY: the guard holds the lock exclusively, and `&mut self`
        // prevents a concurrent `deref` through the same guard.
        self.lock.value.with_mut(|p| unsafe { &mut *p })
    }
}

impl<T> Drop for SpinLockGuard<'_, T> {
    fn drop(&mut self) {
        self.lock.locked.store(false, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn spinlock_mutual_exclusion() {
        let lock = Arc::new(SpinLock::new(0u64));
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let lock = Arc::clone(&lock);
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        *lock.lock() += 1;
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(*lock.lock(), 40_000);
    }

    #[test]
    fn try_lock_fails_when_held() {
        let lock = SpinLock::new(());
        let g = lock.lock();
        assert!(lock.try_lock().is_none());
        assert!(lock.is_locked());
        drop(g);
        assert!(lock.try_lock().is_some());
    }
}
