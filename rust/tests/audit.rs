//! Correctness-observatory integration tests (DESIGN.md §10): the
//! approximation-error auditor must read exactly zero at quiescence, and
//! the invariant watchdog must stay silent through interleaved
//! maintenance and through the PR 7 storage-fault chaos plans.

use std::time::{Duration, Instant};

use mcprioq::audit::{AuditConfig, Auditor};
use mcprioq::config::{PersistSection, ServerConfig};
use mcprioq::coordinator::{Engine, Health};
use mcprioq::persist::open_engine;
use mcprioq::testutil::TempDir;

/// Deterministic xorshift stream for the interleaved workload.
fn stream(n: u64, mut seed: u64) -> Vec<(u64, u64)> {
    (0..n)
        .map(|_| {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            (seed % 31, (seed >> 8) % 17 + 1)
        })
        .collect()
}

fn audit_cfg() -> AuditConfig {
    AuditConfig { sample_nodes: 64, topk: 8, check_nodes: 4096, ..AuditConfig::default() }
}

fn wait_healthy(engine: &Engine, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    while engine.health() != Health::Healthy {
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    true
}

/// Property: total probability mass is conserved across interleaved
/// decay / repair / observe — once quiescent (and after a repair rebases
/// any increment-vs-decay fused-sum skew), every node's full-depth read
/// sums to 1, the audit probe's mass error reads exactly 0, and the
/// watchdog sees zero violations. At 1, 2, and 8 shards.
#[test]
fn mass_conserved_across_interleaved_maintenance() {
    for shards in [1usize, 2, 8] {
        let mut cfg = ServerConfig { shards, queue_capacity: 65_536, ..Default::default() };
        // Staleness bound 0: every read rebuilds its snapshot, so a
        // quiescent probe compares two views of identical state.
        cfg.chain.snap_staleness = 0;
        let engine = Engine::new(&cfg, 2);

        let pairs = stream(15_000, 0x5EED ^ shards as u64);
        for (round, chunk) in pairs.chunks(500).enumerate() {
            engine.observe_batch(chunk);
            match round % 5 {
                3 => {
                    engine.decay();
                }
                4 => {
                    engine.repair();
                }
                _ => {}
            }
            // Reads interleave too: they publish the snapshots the
            // auditor probes (and the paper's read path serves).
            engine.infer_topk(chunk[0].0, 4);
        }
        engine.quiesce();
        // Rebase any fused-sum skew left by increments racing decay's
        // total halving, then publish fresh snapshots everywhere.
        engine.repair();
        for src in 0..31u64 {
            engine.infer_topk(src, 8);
        }

        // Full-depth mass: every live src's probabilities sum to 1.
        let mut live_srcs = 0;
        for src in 0..31u64 {
            let rec = engine.infer_topk(src, 64);
            if rec.items.is_empty() {
                continue;
            }
            live_srcs += 1;
            assert!(
                (rec.cumulative - 1.0).abs() < 1e-9,
                "shards={shards} src={src}: mass {} != 1",
                rec.cumulative
            );
        }
        assert!(live_srcs > 0, "shards={shards}: workload left no live nodes");

        // The audit probe agrees: exact at quiescence.
        let samples = engine.audit_error_samples(64, 8);
        assert!(!samples.is_empty(), "shards={shards}: no snapshot-bearing nodes to probe");
        for s in &samples {
            assert_eq!(s.staleness, 0, "shards={shards} src={}: stale snapshot", s.src);
            assert_eq!(s.rank_inversions, 0, "shards={shards} src={}", s.src);
            assert_eq!(s.displacement, 0, "shards={shards} src={}", s.src);
            assert_eq!(s.mass_error, 0.0, "shards={shards} src={}", s.src);
        }

        // And the watchdog stays silent over the whole structure.
        let mut auditor = Auditor::new(engine.telemetry(), audit_cfg());
        let mut violations = 0;
        for _ in 0..8 {
            violations += engine.audit_round(&mut auditor, None);
        }
        assert_eq!(violations, 0, "shards={shards}: invariant violations at quiescence");
        assert_eq!(engine.health(), Health::Healthy);
        engine.shutdown();
    }
}

/// The PR 7 chaos suite under the watchdog: a seeded ENOSPC window parks
/// batches and degrades the engine, but no structural invariant may ever
/// break — the audit total must be exactly zero before, during, and
/// after the fault, and the engine must still heal.
#[test]
fn chaos_fault_plan_yields_zero_invariant_violations() {
    for shards in [1usize, 2, 8] {
        let tmp = TempDir::new(&format!("audit-chaos-{shards}"));
        let config = ServerConfig {
            shards,
            queue_capacity: 65_536,
            persist: PersistSection {
                data_dir: tmp.join("run").to_string_lossy().into_owned(),
                fsync: "never".into(),
                checkpoint_interval_ms: 0,
                fault_plan: "seed=11;enospc_after=16384;enospc_window_ms=200".into(),
                ..PersistSection::default()
            },
            ..Default::default()
        };
        let (engine, _) = open_engine(&config, 2).unwrap();
        let mut auditor = Auditor::new(engine.telemetry(), audit_cfg());

        let pairs = stream(30_000, 0xC0FFEE ^ shards as u64);
        let mut violations = 0u64;
        for chunk in pairs.chunks(256) {
            engine.observe_batch(chunk);
            engine.infer_topk(chunk[0].0, 4);
            violations += engine.audit_round(&mut auditor, None);
        }
        engine.quiesce();
        assert!(
            wait_healthy(&engine, Duration::from_secs(30)),
            "shards={shards}: never healed; reason={}",
            engine.health_reason()
        );
        // Post-heal: checkpoint so the ckpt-chain check sees a real
        // generation, then keep auditing through decay + repair.
        engine.checkpoint().unwrap();
        engine.decay();
        engine.repair();
        for _ in 0..16 {
            violations += engine.audit_round(&mut auditor, None);
        }
        assert_eq!(violations, 0, "shards={shards}: chaos run broke an invariant");
        assert_eq!(engine.health(), Health::Healthy, "{}", engine.health_reason());

        // The exposition carries the observatory families with every
        // violation counter at zero.
        let mut body = String::new();
        engine.render_metrics(&mut body);
        for family in [
            "mcprioq_audit_rank_error",
            "mcprioq_audit_mass_error",
            "mcprioq_audit_staleness",
            "mcprioq_invariant_violations_total",
        ] {
            assert!(body.contains(family), "missing {family} in exposition");
        }
        for line in body.lines() {
            if line.starts_with("mcprioq_invariant_violations_total") {
                let v: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
                assert_eq!(v, 0.0, "nonzero violation counter: {line}");
            }
        }
        engine.shutdown();
    }
}
