//! Replication differentials (DESIGN.md §5):
//!
//! * Full-stream equality: a follower that consumed the whole stream is
//!   export-identical (`export_quiesced`) to the leader once it reports
//!   lag 0, across 1/2/8 shard layouts; reads are served with the same
//!   answers, writes are rejected until `PROMOTE`.
//! * Kill-the-leader: a follower cut off mid-stream holds exactly a
//!   per-shard prefix of the leader's acked WAL, keeps serving reads, and
//!   catches back up after the leader restarts on the same address.
//! * Snapshot bootstrap: a follower joining after the leader truncated
//!   its early segments boots via the checkpoint codec and converges to
//!   the same state as one that consumed the stream from seq 1.
//! * Maintenance-as-data (DESIGN.md §6): a follower that dies abruptly
//!   releases its leader-side retention pin; a promoted follower applies
//!   streamed decay records exactly once (its local WAL is the witness).

use std::sync::Arc;
use std::time::{Duration, Instant};

use mcprioq::config::{PersistSection, ReplicateSection, ServerConfig};
use mcprioq::coordinator::{Client, Engine, Request, Response, Server};
use mcprioq::persist::{open_engine, wal};
use mcprioq::replicate::{start_follower, ChaosPlan, FollowerHandle};
use mcprioq::testutil::{Rng64, TempDir};

/// A skewed stream with frequent same-src runs (as the persist tests use).
fn stream(len: usize, seed: u64) -> Vec<(u64, u64)> {
    let mut rng = Rng64::new(seed);
    let mut out = Vec::with_capacity(len);
    let mut src = 0u64;
    for i in 0..len {
        if i % 4 == 0 {
            src = rng.next_below(48);
        }
        let u = rng.next_f64();
        out.push((src, ((u * u) * 96.0) as u64));
    }
    out
}

fn durable_config(dir: &std::path::Path, shards: usize) -> ServerConfig {
    ServerConfig {
        shards,
        queue_capacity: 4_096,
        persist: PersistSection {
            data_dir: dir.to_string_lossy().into_owned(),
            fsync: "never".into(),
            checkpoint_interval_ms: 0,
            ..PersistSection::default()
        },
        replicate: ReplicateSection {
            // Fast heartbeats keep the lag gauges fresh in short tests.
            heartbeat_ms: 25,
            connect_timeout_ms: 10_000,
            ..ReplicateSection::default()
        },
        ..Default::default()
    }
}

/// Reserve an address the test can re-bind after a "crash" (the follower
/// reconnects to a fixed leader address, so port 0 won't do).
fn reserve_addr() -> String {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);
    addr
}

/// Block until the leader's WAL heads are fully applied by the follower.
fn catch_up(leader: &Engine, follower: &FollowerHandle, timeout: Duration) {
    let target = leader.stats().wal_last_seqs;
    assert!(
        follower.wait_caught_up(&target, timeout),
        "follower stuck behind {target:?} at {:?} (fault: {:?})",
        follower.state.applied_seqs(),
        follower.state.fault()
    );
}

#[test]
fn follower_full_stream_matches_leader_across_layouts() {
    for shards in [1usize, 2, 8] {
        let ltmp = TempDir::new("repl-leader");
        let ftmp = TempDir::new("repl-follower");
        let lcfg = durable_config(ltmp.path(), shards);
        let (leader, _) = open_engine(&lcfg, 2).unwrap();
        let server = Server::bind(Arc::clone(&leader), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let _lh = server.spawn();

        let follower =
            start_follower(durable_config(ftmp.path(), shards), 1, &addr).unwrap();
        assert!(!follower.state.snapshot_bootstrap(), "{shards} shards: log catch-up");

        // Feed the leader over the wire while the follower streams live.
        let mut client = Client::connect(&addr).unwrap();
        let pairs = stream(20_000, 0xAB5 + shards as u64);
        for chunk in pairs.chunks(997) {
            assert_eq!(client.observe_batch(chunk).unwrap(), chunk.len());
        }
        leader.quiesce();
        catch_up(&leader, &follower, Duration::from_secs(20));

        // The acceptance bar: byte-identical quiesced exports.
        assert_eq!(
            leader.export_quiesced(),
            follower.engine.export_quiesced(),
            "{shards} shards"
        );

        // The follower front-end serves the same reads, rejects writes,
        // and reports its role.
        let fsrv = Server::bind_replica(
            Arc::clone(&follower.engine),
            "127.0.0.1:0",
            Arc::clone(&follower.state),
        )
        .unwrap();
        let faddr = fsrv.local_addr();
        let _fh = fsrv.spawn();
        let mut fclient = Client::connect(faddr).unwrap();
        let hot = pairs[0].0;
        assert_eq!(
            fclient.topk(hot, 5).unwrap(),
            client.topk(hot, 5).unwrap(),
            "{shards} shards replica read"
        );
        match fclient.request(&Request::ObserveBatch { pairs: vec![(1, 2)], id: None }).unwrap() {
            Response::Err(e) => assert!(e.contains("read-only"), "{e}"),
            other => panic!("write on follower must fail, got {other:?}"),
        }
        let stats = fclient.stats().unwrap();
        assert!(stats.contains("role=follower"), "{stats}");
        assert!(stats.contains("lag_records=0"), "{stats}");
        assert!(stats.contains("wal_epoch=1"), "{stats}");
        let lstats = client.stats().unwrap();
        assert!(lstats.contains("repl_followers=1"), "{lstats}");

        follower.engine.shutdown();
        leader.shutdown();
    }
}

#[test]
fn promote_flips_follower_writable() {
    let ltmp = TempDir::new("promote-leader");
    let ftmp = TempDir::new("promote-follower");
    let (leader, _) = open_engine(&durable_config(ltmp.path(), 2), 2).unwrap();
    let server = Server::bind(Arc::clone(&leader), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let _lh = server.spawn();

    // PROMOTE against a leader is a clean error.
    let mut lclient = Client::connect(&addr).unwrap();
    match lclient.request(&Request::Promote).unwrap() {
        Response::Err(e) => assert!(e.contains("not a follower"), "{e}"),
        other => panic!("expected ERR, got {other:?}"),
    }

    let follower = start_follower(durable_config(ftmp.path(), 2), 1, &addr).unwrap();
    lclient.observe_batch(&stream(2_000, 0x9E)).unwrap();
    leader.quiesce();
    catch_up(&leader, &follower, Duration::from_secs(10));

    let fsrv = Server::bind_replica(
        Arc::clone(&follower.engine),
        "127.0.0.1:0",
        Arc::clone(&follower.state),
    )
    .unwrap();
    let faddr = fsrv.local_addr();
    let _fh = fsrv.spawn();
    let mut fclient = Client::connect(faddr).unwrap();
    assert!(matches!(
        fclient.request(&Request::ObserveBatch { pairs: vec![(7, 8)], id: None }).unwrap(),
        Response::Err(_)
    ));
    match fclient.request(&Request::Promote).unwrap() {
        Response::Ok(msg) => assert!(msg.contains("promoted"), "{msg}"),
        other => panic!("expected OK, got {other:?}"),
    }
    // Writes now land: the promoted follower is a leader with the
    // replicated history plus its own WAL continuation. Src 1000 is
    // outside the replicated stream's range, so the top-1 is exact.
    assert_eq!(fclient.observe_batch(&[(1000, 8), (1000, 8), (1000, 9)]).unwrap(), 3);
    follower.engine.quiesce();
    let top = fclient.topk(1000, 1).unwrap();
    assert_eq!(top[0].0, 8);
    let stats = fclient.stats().unwrap();
    assert!(stats.contains("promoted=1"), "{stats}");

    follower.engine.shutdown();
    leader.shutdown();
}

#[test]
fn leader_crash_leaves_prefix_then_reconnect_converges() {
    let ltmp = TempDir::new("crash-leader");
    let ftmp = TempDir::new("crash-follower");
    let addr = reserve_addr();
    let shards = 2usize;
    let lcfg = durable_config(ltmp.path(), shards);
    let pairs = stream(24_000, 0xDEAD);
    let (half_a, half_b) = pairs.split_at(pairs.len() / 2);

    let (leader, _) = open_engine(&lcfg, 2).unwrap();
    let server = Server::bind(Arc::clone(&leader), &addr).unwrap();
    let lh = server.spawn();
    let follower = start_follower(durable_config(ftmp.path(), shards), 1, &addr).unwrap();

    // Feed and kill mid-stream: no quiesce barrier for the follower, the
    // stream just stops wherever it stops.
    for chunk in half_a.chunks(503) {
        assert_eq!(leader.observe_batch(chunk), chunk.len());
    }
    leader.quiesce(); // leader-side only: every fed batch is acked + logged
    let leader_seqs = leader.stats().wal_last_seqs;
    drop(lh); // stop flag: streamer threads exit, connection drops
    leader.shutdown();
    drop(leader);

    // The follower notices, keeps serving, and settles on a prefix.
    let deadline = Instant::now() + Duration::from_secs(10);
    while follower.state.connected() && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(!follower.state.connected(), "follower must notice the dead leader");
    let mut applied = follower.state.applied_seqs();
    loop {
        std::thread::sleep(Duration::from_millis(100));
        let again = follower.state.applied_seqs();
        if again == applied {
            break;
        }
        applied = again;
    }
    for (shard, (&got, &acked)) in applied.iter().zip(&leader_seqs).enumerate() {
        assert!(got <= acked, "shard {shard}: follower at {got}, leader acked {acked}");
    }

    // Prefix check: the follower equals a reference fed exactly the WAL
    // records it applied, per shard, straight from the leader's log.
    let reference = Engine::new(
        &ServerConfig { shards, queue_capacity: 4_096, ..Default::default() },
        0,
    );
    for (shard, &upto) in applied.iter().enumerate() {
        let dir = ltmp.join(&format!("wal/e1/shard-{shard:04}"));
        wal::replay_dir(&dir, 0, |seq, op| {
            if seq <= upto {
                match op {
                    mcprioq::persist::codec::WalOp::Batch(batch) => {
                        reference.observe_batch_direct(&batch)
                    }
                    other => panic!("unexpected record {other:?}"),
                }
            }
        })
        .unwrap();
    }
    assert_eq!(follower.engine.export_quiesced(), reference.export());
    reference.shutdown();

    // Restart the leader on the same address: recovery + reconnect, then
    // the second half flows and both sides converge.
    let (leader, report) = open_engine(&lcfg, 2).unwrap();
    assert!(report.replayed_batches > 0);
    let server = Server::bind(Arc::clone(&leader), &addr).unwrap();
    let _lh = server.spawn();
    let mut client = Client::connect_with_backoff(&addr, Duration::from_secs(5)).unwrap();
    for chunk in half_b.chunks(503) {
        assert_eq!(client.observe_batch(chunk).unwrap(), chunk.len());
    }
    leader.quiesce();
    catch_up(&leader, &follower, Duration::from_secs(20));
    assert_eq!(leader.export_quiesced(), follower.engine.export_quiesced());
    assert!(follower.state.fault().is_none());

    follower.engine.shutdown();
    leader.shutdown();
}

#[test]
fn snapshot_bootstrap_matches_full_stream_follower() {
    let ltmp = TempDir::new("snap-leader");
    let btmp = TempDir::new("snap-follower-b");
    let atmp = TempDir::new("snap-follower-a");
    let shards = 2usize;
    let mut lcfg = durable_config(ltmp.path(), shards);
    // Tiny segments so checkpoint truncation actually removes early ones.
    lcfg.persist.segment_bytes = 2_048;

    let (leader, _) = open_engine(&lcfg, 2).unwrap();
    let server = Server::bind(Arc::clone(&leader), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let _lh = server.spawn();

    // Follower B consumes the stream from seq 1.
    let follower_b = start_follower(durable_config(btmp.path(), shards), 1, &addr).unwrap();
    let mut client = Client::connect(&addr).unwrap();
    client.observe_batch(&stream(10_000, 0x50AB)).unwrap();
    leader.quiesce();
    catch_up(&leader, &follower_b, Duration::from_secs(20));

    // Two checkpoints: lag-one truncation then deletes segments below the
    // first generation's cuts, leaving a WAL that no longer reaches seq 1.
    leader.checkpoint().unwrap();
    let summary = leader.checkpoint().unwrap();
    assert!(summary.wal_freed > 0, "truncation must have removed early segments");
    let truncated = (0..shards).any(|shard| {
        let dir = ltmp.join(&format!("wal/e1/shard-{shard:04}"));
        wal::scan_segments(&dir)
            .unwrap()
            .first()
            .is_some_and(|s| s.first_seq > 1)
    });
    assert!(truncated, "expected at least one shard to lose its seq-1 segment");

    // Follower A joins now: log catch-up is impossible, so the handshake
    // must take the snapshot path.
    let follower_a = start_follower(durable_config(atmp.path(), shards), 1, &addr).unwrap();
    assert!(follower_a.state.snapshot_bootstrap(), "expected snapshot bootstrap");
    assert!(!follower_b.state.snapshot_bootstrap());

    // More traffic after the bootstrap, then everything converges.
    client.observe_batch(&stream(4_000, 0x50AC)).unwrap();
    leader.quiesce();
    catch_up(&leader, &follower_a, Duration::from_secs(20));
    catch_up(&leader, &follower_b, Duration::from_secs(20));
    let reference = leader.export_quiesced();
    assert_eq!(follower_a.engine.export_quiesced(), reference, "snapshot+stream");
    assert_eq!(follower_b.engine.export_quiesced(), reference, "stream from seq 1");

    // A promoted snapshot-bootstrapped follower is durable on its own:
    // reopening its data dir without any leader reproduces the state.
    follower_a.stop();
    drop(follower_a);
    let (reopened, _) = open_engine(&durable_config(atmp.path(), shards), 0).unwrap();
    assert_eq!(reopened.export(), reference, "follower data dir recovers standalone");
    reopened.shutdown();

    follower_b.engine.shutdown();
    leader.shutdown();
}

/// Link chaos (DESIGN.md §8): duplicated records, added latency, severed
/// connections, and a no-redial partition window must never diverge the
/// follower. Dedup by seq, reconnect-and-resume from applied seqs, and
/// dial suppression all compose into byte-identical convergence.
#[test]
fn chaotic_link_still_converges() {
    let plans = [
        // Retransmits on a slow link: every 3rd record arrives twice
        // (exercising the apply plane's `seq <= applied` dedup), 1ms of
        // added latency per record.
        ChaosPlan { dup_every: 3, delay_ms: 1, ..Default::default() },
        // A flappy link with a real outage: every 5th record severs the
        // connection mid-flight (the leader re-streams it after the
        // handshake), and the 12th starts a 300ms partition during which
        // redial is suppressed.
        ChaosPlan {
            drop_every: 5,
            partition_after: 12,
            partition_ms: 300,
            ..Default::default()
        },
    ];
    for (i, plan) in plans.into_iter().enumerate() {
        let ltmp = TempDir::new(&format!("chaos-leader-{i}"));
        let ftmp = TempDir::new(&format!("chaos-follower-{i}"));
        let shards = 2usize;
        let (leader, _) = open_engine(&durable_config(ltmp.path(), shards), 2).unwrap();
        let server = Server::bind(Arc::clone(&leader), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let _lh = server.spawn();

        let mut fcfg = durable_config(ftmp.path(), shards);
        fcfg.replicate.chaos = Some(plan);
        let follower = start_follower(fcfg, 1, &addr).unwrap();

        let mut client = Client::connect(&addr).unwrap();
        let pairs = stream(16_000, 0xC405 + i as u64);
        for chunk in pairs.chunks(499) {
            assert_eq!(client.observe_batch(chunk).unwrap(), chunk.len());
        }
        leader.quiesce();
        catch_up(&leader, &follower, Duration::from_secs(30));
        assert_eq!(
            leader.export_quiesced(),
            follower.engine.export_quiesced(),
            "plan {plan:?}"
        );
        // Chaos is link noise, not a replication fault: nothing latches.
        assert!(follower.state.fault().is_none(), "plan {plan:?}");
        follower.engine.shutdown();
        leader.shutdown();
    }
}

#[test]
fn abrupt_follower_death_releases_leader_pin() {
    let ltmp = TempDir::new("pin-leader");
    let lcfg = durable_config(ltmp.path(), 1);
    let (leader, _) = open_engine(&lcfg, 1).unwrap();
    let server = Server::bind(Arc::clone(&leader), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let _lh = server.spawn();
    assert_eq!(leader.observe_batch(&stream(2_000, 0xF01)), 2_000);
    leader.quiesce();

    // A raw "follower": HELLO, then vanish without ever reading the
    // stream — the abrupt-death shape a SIGKILLed process produces.
    let mut raw = std::net::TcpStream::connect(&addr).unwrap();
    std::io::Write::write_all(&mut raw, b"REPL HELLO 1 1 0\n").unwrap();
    let mut client = Client::connect(&addr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if client.stats().unwrap().contains("repl_followers=1") {
            break;
        }
        assert!(Instant::now() < deadline, "pin never registered");
        std::thread::sleep(Duration::from_millis(10));
    }
    drop(raw);

    // The leader's next write (records or the 25ms heartbeat) fails and
    // the PinGuard releases the retention pin.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if client.stats().unwrap().contains("repl_followers=0") {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "dead follower still pins the WAL: {}",
            client.stats().unwrap()
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    // And truncation is unconstrained again: with traffic + two
    // checkpoints, lag-one truncation actually frees segments.
    assert_eq!(leader.observe_batch(&stream(2_000, 0xF02)), 2_000);
    leader.quiesce();
    leader.checkpoint().unwrap();
    assert_eq!(leader.observe_batch(&stream(2_000, 0xF03)), 2_000);
    leader.quiesce();
    let summary = leader.checkpoint().unwrap();
    assert!(summary.wal_freed > 0, "released pin must unblock truncation");
    leader.shutdown();
}

#[test]
fn promoted_follower_applies_streamed_decay_exactly_once() {
    let ltmp = TempDir::new("middecay-leader");
    let ftmp = TempDir::new("middecay-follower");
    let shards = 2usize;
    let (leader, _) = open_engine(&durable_config(ltmp.path(), shards), 2).unwrap();
    let server = Server::bind(Arc::clone(&leader), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let _lh = server.spawn();
    let follower = start_follower(durable_config(ftmp.path(), shards), 1, &addr).unwrap();

    // Feed, then a leader decay (one DecayRecord per shard), then feed.
    assert_eq!(leader.observe_batch(&stream(8_000, 0xDCA)), 8_000);
    leader.quiesce();
    leader.decay();
    assert_eq!(leader.observe_batch(&stream(4_000, 0xDCB)), 4_000);
    leader.quiesce();
    catch_up(&leader, &follower, Duration::from_secs(20));
    assert_eq!(leader.export_quiesced(), follower.engine.export_quiesced());
    // The follower replayed exactly one decay pass per shard.
    let fstats = follower.engine.stats();
    assert_eq!(fstats.decays_per_shard, vec![1u64; shards]);
    assert_eq!(fstats.decays, shards as u64, "sum aggregate (satellite fix)");

    // Second decay + tail, then promote IMMEDIATELY — records may still
    // be queued in the apply plane. Promotion must drain them (writable
    // gate) and never double-apply a decay interval.
    leader.decay();
    assert_eq!(leader.observe_batch(&stream(2_000, 0xDCC)), 2_000);
    leader.quiesce();
    follower.promote();
    let deadline = Instant::now() + Duration::from_secs(10);
    while !follower.state.writable() && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(follower.state.writable(), "apply plane must drain after promote");
    assert!(follower.state.fault().is_none());

    // The witness: per-shard applied decay passes equal the decay records
    // in the follower's own WAL (appended 1:1 before apply). A local
    // scheduler or a replayed duplicate would break the equality.
    follower.engine.quiesce();
    let fstats = follower.engine.stats();
    for shard in 0..shards {
        let dir = ftmp.join(&format!("wal/e1/shard-{shard:04}"));
        let mut decay_records = 0u64;
        wal::replay_dir(&dir, 0, |_seq, op| {
            if matches!(op, mcprioq::persist::codec::WalOp::Decay { .. }) {
                decay_records += 1;
            }
        })
        .unwrap();
        assert!(decay_records <= 2, "shard {shard}: {decay_records} decay records");
        assert_eq!(
            fstats.decays_per_shard[shard], decay_records,
            "shard {shard}: decay applied != decay logged"
        );
    }

    follower.engine.shutdown();
    leader.shutdown();
}
