//! Batch-first differential oracle: the same input stream driven through
//! every ingestion shape must build the *same model*.
//!
//! Deterministic shapes (compared byte-for-byte via `export()`):
//!   * single `McPrioQ::observe`
//!   * `McPrioQ::observe_batch` in arbitrary chunk sizes
//!   * `Engine` queued single (`observe` -> per-shard queue -> worker)
//!   * `Engine` queued batched (`observe_batch` -> bulk push -> worker)
//!
//! Queued ingestion is deterministic because routing is a pure hash, each
//! shard queue preserves FIFO, and exactly one worker consumes each shard.
//!
//! Plus a concurrent batch-vs-single stress test: interleavings differ, so
//! exports are compared as canonicalized (sorted) edge multisets, and both
//! chains must pass `check_invariants` after repair.

use std::sync::Arc;

use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::config::ServerConfig;
use mcprioq::coordinator::Engine;
use mcprioq::testutil::Rng64;

/// A skewed stream with frequent same-src runs (the batch fast path).
fn stream(len: usize, seed: u64) -> Vec<(u64, u64)> {
    let mut rng = Rng64::new(seed);
    let mut out = Vec::with_capacity(len);
    let mut src = 0u64;
    for i in 0..len {
        // Switch src every few transitions so batches contain runs.
        if i % 4 == 0 {
            src = rng.next_below(48);
        }
        let u = rng.next_f64();
        let dst = ((u * u) * 96.0) as u64;
        out.push((src, dst));
    }
    out
}

#[test]
fn all_ingestion_paths_build_identical_models() {
    let pairs = stream(30_000, 0xD1FF);
    let config = ServerConfig { shards: 3, queue_capacity: 4_096, ..Default::default() };

    let single = McPrioQ::new(ChainConfig::default());
    for &(s, d) in &pairs {
        single.observe(s, d);
    }
    let reference = single.export();

    for chunk_size in [1usize, 7, 256, 5_000] {
        let batched = McPrioQ::new(ChainConfig::default());
        for chunk in pairs.chunks(chunk_size) {
            batched.observe_batch(chunk);
        }
        assert_eq!(reference, batched.export(), "chunk size {chunk_size}");
        batched.check_invariants().unwrap();
    }

    let queued_single = Engine::new(&config, 2);
    for &(s, d) in &pairs {
        assert!(queued_single.observe(s, d));
    }
    queued_single.quiesce();
    assert_eq!(reference, queued_single.export());

    let queued_batched = Engine::new(&config, 3);
    for chunk in pairs.chunks(211) {
        assert_eq!(queued_batched.observe_batch(chunk), chunk.len());
    }
    queued_batched.quiesce();
    assert_eq!(reference, queued_batched.export());
    for chain in queued_batched.chains() {
        chain.check_invariants().unwrap();
    }

    queued_single.shutdown();
    queued_batched.shutdown();
}

/// Read-path differential: at quiescence, answers served from the
/// prefix-sum snapshots must be byte-identical to the live list walk —
/// across the engine (sharded, queued-ingested) as well as the bare chain,
/// for every query shape the wire protocol serves.
#[test]
fn snapshot_and_list_walk_reads_identical_at_quiescence() {
    let pairs = stream(25_000, 0x5EAD);
    let mut config_on = ServerConfig { shards: 3, queue_capacity: 4_096, ..Default::default() };
    config_on.chain.snap_staleness = 64;
    let mut config_off = config_on.clone();
    config_off.chain.snap_enabled = false;

    let snap_on = Engine::new(&config_on, 2);
    let snap_off = Engine::new(&config_off, 2);
    for chunk in pairs.chunks(501) {
        assert_eq!(snap_on.observe_batch(chunk), chunk.len());
        assert_eq!(snap_off.observe_batch(chunk), chunk.len());
    }
    snap_on.quiesce();
    snap_off.quiesce();
    // Same model before comparing answers (queued ingestion is
    // deterministic, so this must already hold).
    assert_eq!(snap_on.export(), snap_off.export());

    for src in 0..48u64 {
        for k in [1usize, 4, 100] {
            snap_on.infer_topk(src, k); // first read builds the snapshot
            assert_eq!(snap_on.infer_topk(src, k), snap_off.infer_topk(src, k), "src {src} k {k}");
        }
        for t in [0.0, 0.5, 0.9, 1.0] {
            snap_on.infer_threshold(src, t);
            assert_eq!(
                snap_on.infer_threshold(src, t),
                snap_off.infer_threshold(src, t),
                "src {src} t {t}"
            );
        }
    }
    let on_stats = snap_on.stats();
    assert!(on_stats.snap_rebuilds > 0, "snapshots never built");
    assert!(on_stats.snap_hits > 0, "snapshots never hit");
    assert_eq!(snap_off.stats().snap_hits, 0);
    snap_on.shutdown();
    snap_off.shutdown();
}

/// Canonicalize an export for cross-interleaving comparison: per-node edge
/// lists sorted by dst (order within a node depends on tie interleaving).
fn canonical(mut snap: Vec<(u64, u64, Vec<(u64, u64)>)>) -> Vec<(u64, u64, Vec<(u64, u64)>)> {
    for (_, _, edges) in &mut snap {
        edges.sort_unstable();
    }
    snap
}

#[test]
fn concurrent_batch_vs_single_same_distribution() {
    const THREADS: u64 = 6;
    const OPS: u64 = 12_000;
    let batched = Arc::new(McPrioQ::new(ChainConfig::default()));
    let single = Arc::new(McPrioQ::new(ChainConfig::default()));

    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let batched = Arc::clone(&batched);
            let single = Arc::clone(&single);
            std::thread::spawn(move || {
                // Every thread applies the *same* per-thread stream to both
                // chains: singles to one, batches of 89 to the other.
                let pairs = stream(OPS as usize, 0xC0FFEE + t);
                for chunk in pairs.chunks(89) {
                    for &(s, d) in chunk {
                        single.observe(s, d);
                    }
                    batched.observe_batch(chunk);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    for c in [&batched, &single] {
        c.repair();
        c.check_invariants().unwrap();
        assert_eq!(c.stats().observes, THREADS * OPS);
    }
    // Interleavings differ between the two chains, but the aggregate model
    // must not: same nodes, same edges, same counts.
    assert_eq!(canonical(single.export()), canonical(batched.export()));
}
