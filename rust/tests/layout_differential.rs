//! Layout differential suite (DESIGN.md §7): the Eytzinger + SIMD read
//! path must be **bit-identical** — same dsts, same `f64` bit patterns,
//! same cumulative — to both the PR 2 sorted binary search and the
//! paper's scalar list walk, at quiescence and across decay storms.
//! Exactness is by construction (integer prefix sums, one IEEE division
//! per item on every path), so the assertions compare `to_bits`, not an
//! epsilon.

use mcprioq::chain::{ChainConfig, McPrioQ, Recommendation};
use mcprioq::config::ServerConfig;
use mcprioq::coordinator::Engine;
use mcprioq::testutil::Rng64;

/// The three read paths under test, fed identical operation streams.
struct Trio {
    list: McPrioQ,
    sorted: McPrioQ,
    eytzinger: McPrioQ,
}

impl Trio {
    fn new() -> Trio {
        let cfg = |snap_enabled, layout: &str| ChainConfig {
            snap_enabled,
            snap_layout: mcprioq::chain::SnapLayout::parse(layout).unwrap(),
            // Engage snapshots even on tiny nodes so the layouts are
            // actually exercised at every fanout in the sweep.
            snap_min_edges: 2,
            ..Default::default()
        };
        Trio {
            list: McPrioQ::new(cfg(false, "sorted")),
            sorted: McPrioQ::new(cfg(true, "sorted")),
            eytzinger: McPrioQ::new(cfg(true, "eytzinger")),
        }
    }

    fn each(&self, f: impl Fn(&McPrioQ)) {
        f(&self.list);
        f(&self.sorted);
        f(&self.eytzinger);
    }

    /// Compare every query type on `src` across the three paths.
    fn check_src(&self, src: u64, fanout: usize, ctx: &str) {
        for k in [1usize, 3, 10, fanout, fanout + 7] {
            let reference = self.list.infer_topk(src, k);
            assert_bits_eq(&reference, &self.sorted.infer_topk(src, k), src, &format!("{ctx} sorted topk{k}"));
            assert_bits_eq(&reference, &self.eytzinger.infer_topk(src, k), src, &format!("{ctx} eytzinger topk{k}"));
        }
        for t in [0.0, 0.1, 0.25, 0.5, 0.77, 0.9, 0.999, 1.0] {
            let reference = self.list.infer_threshold(src, t);
            assert_bits_eq(&reference, &self.sorted.infer_threshold(src, t), src, &format!("{ctx} sorted t{t}"));
            assert_bits_eq(&reference, &self.eytzinger.infer_threshold(src, t), src, &format!("{ctx} eytzinger t{t}"));
        }
    }
}

fn assert_bits_eq(a: &Recommendation, b: &Recommendation, src: u64, ctx: &str) {
    assert_eq!(a.total, b.total, "{ctx} src{src}: total");
    assert_eq!(a.items.len(), b.items.len(), "{ctx} src{src}: len");
    for (i, ((ad, ap), (bd, bp))) in a.items.iter().zip(&b.items).enumerate() {
        assert_eq!(ad, bd, "{ctx} src{src}: dst at {i}");
        assert_eq!(
            ap.to_bits(),
            bp.to_bits(),
            "{ctx} src{src}: prob bits at {i} ({ap} vs {bp})"
        );
    }
    assert_eq!(
        a.cumulative.to_bits(),
        b.cumulative.to_bits(),
        "{ctx} src{src}: cumulative ({} vs {})",
        a.cumulative,
        b.cumulative
    );
}

/// Skewed transition stream: src in [0, srcs), dst weight ~ u^3 so the
/// repaired order has real structure (heavy head, long tail).
fn observe_stream(trio: &Trio, rng: &mut Rng64, srcs: u64, fanout: usize, n: usize) {
    for _ in 0..n {
        let src = rng.next_below(srcs);
        let u = rng.next_f64();
        let dst = 1_000 + ((u * u * u) * fanout as f64) as u64;
        trio.each(|c| {
            c.observe(src, dst);
        });
    }
}

#[test]
fn layouts_agree_at_quiescence_across_fanouts() {
    // Fanouts straddle the Eytzinger/SIMD interesting sizes: tiny (below
    // snap_min_edges on some srcs), one SIMD block, the 64-edge
    // acceptance point, non-power-of-two, and large.
    for fanout in [3usize, 8, 64, 100, 300] {
        let trio = Trio::new();
        let mut rng = Rng64::new(0xE1F + fanout as u64);
        observe_stream(&trio, &mut rng, 4, fanout, 6_000);
        trio.each(|c| {
            c.repair();
        });
        for src in 0..4 {
            trio.check_src(src, fanout, &format!("fanout{fanout}"));
        }
    }
}

#[test]
fn layouts_agree_through_decay_storms() {
    let trio = Trio::new();
    let mut rng = Rng64::new(0xDECA);
    for round in 0..6 {
        observe_stream(&trio, &mut rng, 4, 120, 3_000);
        // Storm: several back-to-back decays prune tail edges and
        // invalidate every published snapshot; some rounds skip repair so
        // the snapshots rebuild from a not-recently-repaired list order.
        for _ in 0..1 + round % 3 {
            let expected = trio.list.decay();
            assert_eq!(trio.sorted.decay(), expected, "round {round}: sorted decay");
            assert_eq!(trio.eytzinger.decay(), expected, "round {round}: eytzinger decay");
        }
        if round % 2 == 0 {
            trio.each(|c| {
                c.repair();
            });
        }
        for src in 0..4 {
            trio.check_src(src, 120, &format!("storm round{round}"));
        }
    }
}

/// Readers racing a decay storm on the Eytzinger chain: no panics, and
/// every answer is internally sane (RCU snapshot consistency). Cross-
/// instance equality is only defined at quiescence, so this test checks
/// invariants, not equality.
#[test]
fn eytzinger_reads_survive_a_live_decay_storm() {
    use mcprioq::sync::shim::{AtomicBool, Ordering};
    let chain = std::sync::Arc::new(McPrioQ::new(ChainConfig {
        snap_min_edges: 2,
        ..Default::default()
    }));
    let mut rng = Rng64::new(0x51);
    for _ in 0..20_000 {
        let u = rng.next_f64();
        chain.observe(0, 1_000 + ((u * u * u) * 200.0) as u64);
    }
    chain.repair();
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        for t in 0..3 {
            let chain = std::sync::Arc::clone(&chain);
            let stop = &stop;
            s.spawn(move || {
                let mut rng = Rng64::new(0xBEEF + t);
                let mut out = Recommendation::default();
                while !stop.load(Ordering::Relaxed) {
                    chain.infer_threshold_into(0, rng.next_f64(), &mut out);
                    let mut sum = 0.0f64;
                    for &(_, p) in &out.items {
                        assert!((0.0..=1.0).contains(&p), "prob out of range: {p}");
                        sum += p;
                    }
                    assert!(sum <= 1.0 + 1e-9, "prefix mass > 1: {sum}");
                    chain.infer_topk_into(0, 10, &mut out);
                    assert!(out.items.len() <= 10);
                }
            });
        }
        // The storm: churn + decay + repair while the readers run.
        let mut rng = Rng64::new(0x5117);
        for i in 0..60 {
            for _ in 0..500 {
                let u = rng.next_f64();
                chain.observe(0, 1_000 + ((u * u * u) * 200.0) as u64);
            }
            chain.decay();
            if i % 4 == 0 {
                chain.repair();
            }
        }
        stop.store(true, Ordering::Relaxed);
    });
}

/// The same differential through the engine at 1, 2, and 8 shards: shard
/// routing must not perturb layout equality (each shard is its own
/// McPrioQ; the layout knob arrives via `[chain] snap_layout`).
#[test]
fn sharded_engines_agree_across_layouts() {
    for shards in [1usize, 2, 8] {
        let make = |snap_enabled: bool, layout: &str| {
            let mut cfg = ServerConfig { shards, ..Default::default() };
            cfg.chain.snap_enabled = snap_enabled;
            cfg.chain.snap_min_edges = 2;
            cfg.chain.snap_layout = layout.to_string();
            // Direct-path engines: 0 workers, no queues in the loop.
            Engine::new(&cfg, 0)
        };
        let engines =
            [make(false, "sorted"), make(true, "sorted"), make(true, "eytzinger")];

        let mut rng = Rng64::new(0x5A4D + shards as u64);
        let mut batch = Vec::with_capacity(512);
        for round in 0..3 {
            batch.clear();
            for _ in 0..4_000 {
                let src = rng.next_below(16);
                let u = rng.next_f64();
                batch.push((src, 1_000 + ((u * u * u) * 150.0) as u64));
            }
            for e in &engines {
                e.observe_batch_direct(&batch);
            }
            if round > 0 {
                let expected = engines[0].decay();
                for e in &engines[1..] {
                    assert_eq!(e.decay(), expected, "shards {shards} round {round}: decay");
                }
            }
            for e in &engines {
                e.repair();
            }
            for src in 0..16 {
                for k in [1usize, 5, 40] {
                    let reference = engines[0].infer_topk(src, k);
                    for (i, e) in engines[1..].iter().enumerate() {
                        assert_bits_eq(
                            &reference,
                            &e.infer_topk(src, k),
                            src,
                            &format!("shards {shards} round {round} engine{} topk{k}", i + 1),
                        );
                    }
                }
                for t in [0.3, 0.8, 1.0] {
                    let reference = engines[0].infer_threshold(src, t);
                    for (i, e) in engines[1..].iter().enumerate() {
                        assert_bits_eq(
                            &reference,
                            &e.infer_threshold(src, t),
                            src,
                            &format!("shards {shards} round {round} engine{} t{t}", i + 1),
                        );
                    }
                }
            }
        }
        for e in &engines {
            e.shutdown();
        }
    }
}
