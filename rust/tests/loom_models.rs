//! Loom protocol models (DESIGN.md § Concurrency verification).
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"`, where the sync shim
//! (`rust/src/sync/shim.rs`) resolves every atomic, cell, and lock to the
//! vendored model checker — so each model below drives the *production*
//! code paths (EdgeList ticket protocol, PtrTable migration, RCU guards,
//! SpinLock) through exhaustive-ish schedule exploration with vector-clock
//! race checking. Without the cfg this file compiles to an empty test
//! binary, so `cargo test` stays unaffected.
//!
//! Bounds are deliberately tiny (2-3 threads, a handful of ops): loom-style
//! checking explores interleavings of *synchronization operations*, and the
//! state space is exponential in their count. Each model asserts one
//! protocol invariant that a reordering bug would break.
//!
//! Reproduce a failure: the harness prints the failing iteration's seed;
//! rerun with `LOOM_SEED=<seed> LOOM_ITERATIONS=1`.

#![cfg(loom)]

use std::sync::Arc;

use mcprioq::hashtable::PtrTable;
use mcprioq::prioq::EdgeList;
use mcprioq::rcu;
use mcprioq::sync::shim::{AtomicPtr, Ordering};
use mcprioq::sync::SpinLock;

/// Collect `(key, count)` pairs from the *linked* chain only — `scan`
/// never drains the pending stack, so a node stranded there is invisible.
fn collect(list: &EdgeList) -> Vec<(u64, u64)> {
    let guard = rcu::pin();
    let mut out = Vec::new();
    list.scan(&guard, |k, c| {
        out.push((k, c));
        true
    });
    out
}

/// Regression model for the store-buffering window in the helping
/// protocol (`prioq/list.rs`, the paired SeqCst fences in `push_pending` /
/// `try_maintain`): a pusher that finds the ticket held leaves its node on
/// the pending stack and relies on the holder's post-release re-probe to
/// drain it. If both sides read stale state, the node is stranded: it
/// never reaches the linked chain even though its `insert` returned. Two
/// concurrent inserts must both be linked by the time both calls return.
#[test]
fn pending_handoff_never_strands() {
    loom::model(|| {
        let list = Arc::new(EdgeList::new());
        let t = {
            let list = Arc::clone(&list);
            loom::thread::spawn(move || {
                let guard = rcu::pin();
                list.insert(&guard, 1, 10);
            })
        };
        {
            let guard = rcu::pin();
            list.insert(&guard, 2, 20);
        }
        t.join().unwrap();
        let mut got = collect(&list);
        got.sort_unstable();
        assert_eq!(got, vec![(1, 10), (2, 20)], "a pending insert was stranded");
        assert_eq!(list.len(), 2);
    });
}

/// Concurrent counter increments through the wait-free path (`increment`
/// plus the opportunistic bubble swap under the ticket): no update may be
/// lost or double-applied regardless of how ticket hand-offs interleave.
#[test]
fn increments_never_lost_under_reorder_races() {
    loom::model(|| {
        let list = Arc::new(EdgeList::new());
        {
            let guard = rcu::pin();
            list.insert(&guard, 1, 1);
            list.insert(&guard, 2, 1);
        }
        let t = {
            let list = Arc::clone(&list);
            loom::thread::spawn(move || {
                for key in [1u64, 2] {
                    let guard = rcu::pin();
                    let (node, inserted) = list.find_or_insert(&guard, key, 1);
                    if !inserted {
                        // SAFETY: `node` belongs to `list` and is protected
                        // by `guard` (the find_or_insert contract).
                        unsafe { list.increment(&guard, node, 1) };
                    }
                }
            })
        };
        for key in [2u64, 1] {
            let guard = rcu::pin();
            let (node, inserted) = list.find_or_insert(&guard, key, 1);
            if !inserted {
                // SAFETY: as above — a node of `list` under `guard`.
                unsafe { list.increment(&guard, node, 1) };
            }
        }
        t.join().unwrap();
        let total: u64 = collect(&list).iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 6, "an increment was lost or double-applied");
    });
}

/// Regression model for the StoreLoad window between a slot's insert CAS
/// and its seq validation load (`hashtable/raw.rs`, the SeqCst fence): a
/// writer publishing into an array that a concurrent migrator is retiring
/// must either land in the new array or be carried over by the migration.
/// Tiny capacity forces resizes, so inserts race the migrator directly;
/// every key must survive.
#[test]
fn hashtable_migration_loses_no_inserts() {
    loom::model(|| {
        let table = Arc::new(PtrTable::<u64>::with_capacity(2));
        let t = {
            let table = Arc::clone(&table);
            loom::thread::spawn(move || {
                for key in [1u64, 2, 3] {
                    let guard = rcu::pin();
                    let fresh = Box::into_raw(Box::new(key));
                    let (_, inserted) = table.insert_or_get(&guard, key, fresh);
                    assert!(inserted, "distinct keys cannot collide");
                }
            })
        };
        for key in [4u64, 5, 6] {
            let guard = rcu::pin();
            let fresh = Box::into_raw(Box::new(key));
            let (_, inserted) = table.insert_or_get(&guard, key, fresh);
            assert!(inserted, "distinct keys cannot collide");
        }
        t.join().unwrap();

        let mut values = Vec::new();
        {
            let guard = rcu::pin();
            for key in 1..=6u64 {
                let p = table.get(&guard, key).expect("insert lost in migration");
                // SAFETY: values are live Boxes, freed only after the table
                // (their sole publisher) is gone, below.
                assert_eq!(unsafe { *p }, key);
            }
            table.for_each(&guard, |_, p| values.push(p));
        }
        assert_eq!(values.len(), 6);
        drop(
            Arc::try_unwrap(table).unwrap_or_else(|_| panic!("table still shared after joins")),
        );
        for p in values {
            // SAFETY: the table is dropped, both threads joined — these are
            // the only remaining references, each freed exactly once.
            drop(unsafe { Box::from_raw(p) });
        }
    });
}

/// The publish race `chain::observe_pinned` relies on: two threads racing
/// `insert_or_get` on the same key must agree on a single winner, and the
/// loser's pointer must never become visible to readers.
#[test]
fn insert_or_get_single_winner() {
    loom::model(|| {
        let table = Arc::new(PtrTable::<u64>::with_capacity(4));
        let contend = |table: &PtrTable<u64>, val: u64| -> bool {
            let guard = rcu::pin();
            let fresh = Box::into_raw(Box::new(val));
            let (winner, inserted) = table.insert_or_get(&guard, 9, fresh);
            if inserted {
                assert_eq!(winner, fresh);
            } else {
                assert_ne!(winner, fresh, "loser reported as inserted");
                // SAFETY: we lost the race — `fresh` was never published,
                // this is its only reference.
                drop(unsafe { Box::from_raw(fresh) });
            }
            inserted
        };
        let t = {
            let table = Arc::clone(&table);
            loom::thread::spawn(move || contend(&table, 111))
        };
        let main_won = contend(&table, 222);
        let child_won = t.join().unwrap();
        assert!(main_won ^ child_won, "exactly one publisher must win");

        let guard = rcu::pin();
        let p = table.get(&guard, 9).expect("winner vanished");
        // SAFETY: the winner's Box stays live until freed below.
        let v = unsafe { *p };
        assert!(v == 111 || v == 222);
        drop(guard);
        // SAFETY: threads joined; the winner's Box has exactly one owner.
        drop(unsafe { Box::from_raw(p) });
    });
}

/// RCU's core guarantee, driven through the production guard/collector: a
/// deferred reclamation must not run while any guard pinned before the
/// `defer` can still reach the retired object. The callback poisons the
/// value before freeing, so a premature run is observable as the poison.
#[test]
fn rcu_defer_waits_for_pinned_readers() {
    loom::model(|| {
        let slot = Arc::new(AtomicPtr::new(Box::into_raw(Box::new(7u64))));
        let reader = {
            let slot = Arc::clone(&slot);
            loom::thread::spawn(move || {
                let guard = rcu::pin();
                let p = slot.load(Ordering::Acquire);
                // SAFETY: `p` was published and is retired only via
                // `rcu::defer`; our guard keeps it alive — the assertion
                // below is exactly that guarantee.
                let v = unsafe { *p };
                assert!(v == 7 || v == 42, "read a reclaimed value: {v}");
                drop(guard);
            })
        };
        let guard = rcu::pin();
        let old = slot.swap(Box::into_raw(Box::new(42u64)), Ordering::AcqRel);
        let old_addr = old as usize;
        rcu::defer(&guard, move || {
            let old = old_addr as *mut u64;
            // SAFETY: the collector invokes this only after every guard
            // pinned at defer time has dropped; `old` is unreachable
            // (swapped out) so this is the last reference.
            unsafe {
                *old = 0; // poison: a pinned reader must never see this
                drop(Box::from_raw(old));
            }
        });
        drop(guard);
        rcu::synchronize();
        reader.join().unwrap();

        let last = slot.swap(std::ptr::null_mut(), Ordering::AcqRel);
        rcu::synchronize();
        // SAFETY: unpublished above and all threads joined; sole reference.
        drop(unsafe { Box::from_raw(last) });
    });
}

/// SpinLock mutual exclusion through the shim `UnsafeCell`: the guard's
/// plain `+= 1` is exactly the unsynchronized access loom's race detector
/// would flag if the Acquire/Release pair on `locked` were wrong.
#[test]
fn spinlock_guards_plain_data() {
    loom::model(|| {
        let lock = Arc::new(SpinLock::new(0u64));
        let threads: Vec<_> = (0..2)
            .map(|_| {
                let lock = Arc::clone(&lock);
                loom::thread::spawn(move || {
                    *lock.lock() += 1;
                })
            })
            .collect();
        *lock.lock() += 1;
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(*lock.lock(), 3);
    });
}
