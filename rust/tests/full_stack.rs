//! Integration: the whole serving stack composed end-to-end — config file
//! → engine + decay scheduler + TCP server → workload → verified inference
//! quality — plus a smoke test of the installed binary.

use std::sync::Arc;
use std::time::Duration;

use mcprioq::config::ServerConfig;
use mcprioq::coordinator::{Client, DecayScheduler, Engine, Server};
use mcprioq::workload::{MobilityConfig, MobilityTrace, TransitionStream};

#[test]
fn config_file_to_serving_stack() {
    // Config comes from a real TOML file on disk.
    let dir = std::env::temp_dir().join(format!("mcprioq_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("server.toml");
    std::fs::write(
        &cfg_path,
        "[server]\nlisten = \"127.0.0.1:0\"\nshards = 2\nqueue_capacity = 4096\n\
         decay_interval_ms = 200\n[chain]\nsrc_capacity = 64\n",
    )
    .unwrap();
    let config = ServerConfig::load(cfg_path.to_str().unwrap()).unwrap();
    assert_eq!(config.shards, 2);

    let engine = Engine::new(&config, 2);
    let _decay = DecayScheduler::start(
        Arc::clone(&engine),
        config.decay_interval.unwrap_or(Duration::from_secs(1)),
    );
    let server = Server::bind(Arc::clone(&engine), &config.listen).unwrap();
    let addr = server.local_addr();
    let _handle = server.spawn();

    // Drive a mobility workload through TCP while queries run.
    let mut trace = MobilityTrace::new(MobilityConfig {
        width: 8,
        height: 8,
        users: 40,
        skew: 1.2,
        explore: 0.05,
        seed: 3,
    });
    let mut client = Client::connect(addr).unwrap();
    for _ in 0..30_000 {
        let (a, b) = trace.next_transition();
        client.observe(a, b).unwrap();
    }
    engine.quiesce();

    // Inference quality: the model should page a small set with high
    // success on this strongly-skewed topology.
    let mut hits = 0;
    let mut paged = 0;
    const PROBES: usize = 1_000;
    for _ in 0..PROBES {
        let (from, to) = trace.next_transition();
        let rec = client.recommend(from, 0.9).unwrap();
        if rec.iter().any(|&(c, _)| c == to) {
            hits += 1;
        }
        paged += rec.len();
        client.observe(from, to).unwrap();
    }
    let success = hits as f64 / PROBES as f64;
    let avg_paged = paged as f64 / PROBES as f64;
    assert!(success > 0.80, "paging success {success}");
    assert!(avg_paged < 6.0, "paged set too large: {avg_paged}");

    // Decay scheduler ran and the model stayed consistent.
    std::thread::sleep(Duration::from_millis(450));
    for chain in engine.chains() {
        chain.repair();
        chain.check_invariants().unwrap();
    }
    let stats = client.stats().unwrap();
    assert!(stats.contains("shards=2"), "{stats}");
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn binary_info_smoke() {
    // The built binary answers `info` without a server running.
    let exe = env!("CARGO_BIN_EXE_mcprioq");
    let out = std::process::Command::new(exe).arg("info").output().expect("run binary");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("three-layer build"), "{text}");
}

#[test]
fn binary_usage_on_bad_args() {
    let exe = env!("CARGO_BIN_EXE_mcprioq");
    let out = std::process::Command::new(exe).arg("bogus").output().expect("run binary");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("COMMANDS"), "{err}");
}

/// Backpressure: with tiny queue and no workers, blocking observe stalls
/// until a worker drains — verified by timing.
#[test]
fn ingestion_backpressure_engages() {
    let config = ServerConfig { shards: 1, queue_capacity: 8, ..Default::default() };
    let engine = Engine::new(&config, 1);
    // Saturate: 10k blocking pushes must all be applied, never dropped.
    for i in 0..10_000u64 {
        assert!(engine.observe(i % 50, i % 30));
    }
    engine.quiesce();
    assert_eq!(engine.stats().observes, 10_000);
    assert_eq!(engine.stats().dropped_updates, 0);
    engine.shutdown();
}
