//! Long-horizon differential test: every engine (MCPrioQ with/without dst
//! table, all baselines, and — when artifacts exist — the dense XLA path)
//! is driven through interleaved observe/decay/query cycles and must agree
//! on every answer. This is the repo's strongest cross-layer oracle.

use std::sync::Arc;

use mcprioq::baselines::{HeapChain, MarkovModel, MutexChain, ShardedChain, SkipListChain};
use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::runtime::{default_artifacts_dir, DenseXlaChain, XlaRuntime};
use mcprioq::testutil::Rng64;

const SRCS: u64 = 6;
const DSTS: u64 = 48;
const ROUNDS: usize = 5;
const OBS_PER_ROUND: usize = 3_000;

fn models() -> Vec<Box<dyn MarkovModel>> {
    let mut v: Vec<Box<dyn MarkovModel>> = vec![
        Box::new(McPrioQ::new(ChainConfig::default())),
        Box::new(McPrioQ::new(ChainConfig { use_dst_table: false, ..Default::default() })),
        Box::new(MutexChain::new()),
        Box::new(ShardedChain::new(4)),
        Box::new(SkipListChain::new()),
        Box::new(HeapChain::new()),
    ];
    match XlaRuntime::new(&default_artifacts_dir()) {
        Ok(rt) => {
            v.push(Box::new(DenseXlaChain::new(Arc::new(rt), (SRCS + DSTS) as usize).unwrap()))
        }
        Err(e) => eprintln!("differential: dense engine skipped ({e:#})"),
    }
    v
}

#[test]
fn all_engines_agree_through_decay_cycles() {
    let models = models();
    let mut rng = Rng64::new(0xD1F2);
    for round in 0..ROUNDS {
        for _ in 0..OBS_PER_ROUND {
            let src = rng.next_below(SRCS);
            let u = rng.next_f64();
            let dst = SRCS + ((u * u * u) * DSTS as f64) as u64;
            for m in &models {
                m.observe(src, dst);
            }
        }
        // Cross-check every query type on every src.
        for src in 0..SRCS {
            let reference = models[0].infer_topk(src, 8);
            for m in &models[1..] {
                let got = m.infer_topk(src, 8);
                assert_eq!(got.total, reference.total, "{} r{round} s{src} total", m.name());
                assert_eq!(
                    got.items.len(),
                    reference.items.len(),
                    "{} r{round} s{src} len",
                    m.name()
                );
                for (a, b) in reference.items.iter().zip(&got.items) {
                    assert!(
                        (a.1 - b.1).abs() < 1e-5,
                        "{} r{round} s{src}: {:?} vs {:?}",
                        m.name(),
                        reference.items,
                        got.items
                    );
                }
            }
            for t in [0.4, 0.85] {
                let reference = models[0].infer_threshold(src, t);
                for m in &models[1..] {
                    let got = m.infer_threshold(src, t);
                    // Dense engines cap at compiled k; only compare when
                    // the reference answer fits.
                    if reference.items.len() <= 8 {
                        assert_eq!(
                            got.items.len(),
                            reference.items.len(),
                            "{} r{round} s{src} t{t}",
                            m.name()
                        );
                        assert!(
                            (got.cumulative - reference.cumulative).abs() < 1e-5,
                            "{} r{round} s{src} t{t}: {} vs {}",
                            m.name(),
                            got.cumulative,
                            reference.cumulative
                        );
                    }
                }
            }
        }
        // Decay everywhere; results must agree exactly.
        let expected = models[0].decay();
        for m in &models[1..] {
            assert_eq!(m.decay(), expected, "{} decay r{round}", m.name());
        }
        for m in &models {
            assert_eq!(m.edge_count(), models[0].edge_count(), "{} edges r{round}", m.name());
        }
    }
}
