//! Fault-injection differentials (DESIGN.md §8): drive the engine
//! through injected storage faults and assert the degradation ladder —
//! never a panic, reads served throughout, writes parked or refused,
//! self-heal back to `Healthy`, and recovery byte-identical to a
//! never-faulted reference run.
//!
//! Fault schedules come in through the production entry point
//! (`[persist] fault_plan` → `IoHandle::from_plan`), so these tests
//! exercise exactly the path the CI chaos smoke drives via the hidden
//! `--fault-plan` CLI flag.

use std::time::{Duration, Instant};

use mcprioq::config::{PersistSection, ServerConfig};
use mcprioq::coordinator::{Engine, Health};
use mcprioq::persist::{open_engine, CheckpointScheduler};
use mcprioq::testutil::TempDir;

/// Deterministic update stream shared by faulted and reference runs.
fn pairs(n: u64) -> Vec<(u64, u64)> {
    (0..n).map(|i| (i % 211, i % 97 + 1)).collect()
}

fn durable_config(dir: std::path::PathBuf, shards: usize, plan: &str) -> ServerConfig {
    ServerConfig {
        shards,
        queue_capacity: 65_536,
        persist: PersistSection {
            data_dir: dir.to_string_lossy().into_owned(),
            fsync: "never".into(),
            // Checkpoints are driven explicitly (or by the scheduler test).
            checkpoint_interval_ms: 0,
            fault_plan: plan.to_string(),
            ..PersistSection::default()
        },
        ..Default::default()
    }
}

/// Wait for the heal loop to climb back to `Healthy`.
fn wait_healthy(engine: &Engine, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    while engine.health() != Health::Healthy {
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    true
}

/// The tentpole differential: an ENOSPC window mid-ingest must degrade
/// the engine (batches parked, not lost), keep serving reads, heal once
/// space frees, and leave both the live state and a crash-restart
/// recovery equal to a never-faulted reference — at 1, 2, and 8 shards.
#[test]
fn enospc_window_degrades_heals_and_recovers_equal() {
    for shards in [1usize, 2, 8] {
        let tmp = TempDir::new(&format!("fi-enospc-{shards}"));
        let stream = pairs(30_000);

        // Never-faulted reference.
        let (reference, _) =
            open_engine(&durable_config(tmp.join("ref"), shards, ""), 2).unwrap();
        for chunk in stream.chunks(256) {
            reference.observe_batch(chunk);
        }
        reference.quiesce();
        let expect = reference.export_quiesced();
        reference.shutdown();
        drop(reference);

        // Faulted run: the "disk" fills after 16 KiB, frees 200ms later.
        let plan = "seed=7;enospc_after=16384;enospc_window_ms=200";
        let (engine, _) =
            open_engine(&durable_config(tmp.join("run"), shards, plan), 2).unwrap();
        let mut degraded = false;
        for chunk in stream.chunks(256) {
            engine.observe_batch(chunk);
            degraded |= engine.health() != Health::Healthy;
        }
        // Parked batches count as settled, so quiesce returns even while
        // the WAL is quarantined (acked-at-enqueue exposure, DESIGN.md §8).
        engine.quiesce();
        degraded |= engine.health() != Health::Healthy;

        // Reads are served from the in-memory RCU structures throughout —
        // regardless of which rung the engine is on right now.
        let rec = engine.infer_topk(1, 4);
        assert!(rec.total > 0, "reads must be served during/after the fault");

        assert!(
            wait_healthy(&engine, Duration::from_secs(30)),
            "shards={shards}: engine never healed; health={:?} reason={}",
            engine.health(),
            engine.health_reason()
        );
        let stats = engine.stats();
        // Seeing a heal attempt also proves degradation happened, even if
        // every health() poll above raced past the fault window.
        degraded |= stats.wal_retry > 0;
        assert!(degraded, "shards={shards}: the ENOSPC window never degraded the engine");
        assert_eq!(stats.health, "healthy");

        engine.quiesce();
        assert_eq!(
            engine.export_quiesced(),
            expect,
            "shards={shards}: healed live state diverged from the reference"
        );
        engine.shutdown();
        drop(engine);

        // Crash-restart over the healed WAL: the drained quarantine
        // re-appended every parked batch contiguously, so replay (no
        // fault plan this time) must rebuild the same state.
        let (recovered, report) =
            open_engine(&durable_config(tmp.join("run"), shards, ""), 0).unwrap();
        assert!(report.replayed_updates > 0);
        assert_eq!(
            recovered.export(),
            expect,
            "shards={shards}: recovery after the fault diverged from the reference"
        );
        recovered.shutdown();
    }
}

/// Fsync-schedule sweep over checkpoint commits: every 4th fsync fails
/// with EIO, so checkpoint attempts alternate between success and
/// failure. A failed checkpoint must not degrade the engine (nothing was
/// acked against the torn generation), must not wedge ingest, and
/// recovery must still equal the never-faulted reference at every shard
/// count.
#[test]
fn fsync_faults_during_checkpoints_keep_recovery_equal() {
    for shards in [1usize, 2, 8] {
        let tmp = TempDir::new(&format!("fi-fsync-{shards}"));
        let stream = pairs(12_000);

        let (reference, _) =
            open_engine(&durable_config(tmp.join("ref"), shards, ""), 2).unwrap();
        // With `fsync = never` the only sync_data calls are the
        // checkpointer's (snap, manifest, mark — 3 per clean attempt), so
        // `fail_fsync_every=4` deterministically fails some attempts.
        let plan = "seed=3;fail_fsync_every=4";
        let (engine, _) =
            open_engine(&durable_config(tmp.join("run"), shards, plan), 2).unwrap();

        let (mut ok, mut err) = (0u32, 0u32);
        for chunk in stream.chunks(1000) {
            reference.observe_batch(chunk);
            engine.observe_batch(chunk);
            engine.quiesce();
            match engine.checkpoint() {
                Ok(_) => ok += 1,
                Err(_) => err += 1,
            }
            assert_eq!(
                engine.health(),
                Health::Healthy,
                "a failed checkpoint must not degrade the engine"
            );
        }
        assert!(ok > 0, "shards={shards}: no checkpoint ever committed");
        assert!(err > 0, "shards={shards}: the fsync schedule never fired");

        reference.quiesce();
        let expect = reference.export_quiesced();
        reference.shutdown();
        engine.quiesce();
        assert_eq!(engine.export_quiesced(), expect, "shards={shards}: live divergence");
        engine.shutdown();
        drop(engine);

        let (recovered, _) =
            open_engine(&durable_config(tmp.join("run"), shards, ""), 0).unwrap();
        assert_eq!(
            recovered.export(),
            expect,
            "shards={shards}: recovery through failed checkpoints diverged"
        );
        recovered.shutdown();
    }
}

/// A torn checkpoint commit (the snapshot file truncated to half before
/// its rename, manifest still pointing at it) must fall back to pure WAL
/// replay at recovery — the manifest is a pointer, not the only truth.
#[test]
fn torn_checkpoint_rename_falls_back_to_wal_replay() {
    let tmp = TempDir::new("fi-torn");
    let stream = pairs(8_000);
    let plan = "seed=1;torn_rename_at=1"; // tear the first rename: gen-1's snap
    let (engine, _) = open_engine(&durable_config(tmp.join("run"), 2, plan), 2).unwrap();
    for chunk in stream.chunks(256) {
        engine.observe_batch(chunk);
    }
    engine.quiesce();
    let expect = engine.export_quiesced();
    // The commit "succeeds" (the rename itself goes through) but the
    // committed snapshot is CRC-broken. The first generation truncates no
    // WAL (lag-one), so the full log is still there to fall back to.
    engine.checkpoint().unwrap();
    engine.shutdown();
    drop(engine);

    let (recovered, report) =
        open_engine(&durable_config(tmp.join("run"), 2, ""), 0).unwrap();
    assert_eq!(report.snapshot_nodes, 0, "torn snapshot must not be trusted");
    assert!(report.replayed_updates > 0, "fallback is pure WAL replay");
    assert_eq!(recovered.export(), expect);
    recovered.shutdown();
}

/// The background checkpoint scheduler must survive I/O errors: a failed
/// generation marks `has_failed`, the scheduler keeps running on capped
/// backoff, and a later attempt commits once the fault schedule moves on.
#[test]
fn checkpoint_scheduler_survives_io_errors() {
    let tmp = TempDir::new("fi-sched");
    // fail_fsync_at=2 fails exactly the first attempt's manifest commit;
    // every later attempt is clean.
    let plan = "seed=2;fail_fsync_at=2";
    let (engine, _) = open_engine(&durable_config(tmp.join("run"), 2, plan), 2).unwrap();
    for chunk in pairs(4_000).chunks(256) {
        engine.observe_batch(chunk);
    }
    engine.quiesce();
    let sched =
        CheckpointScheduler::start(std::sync::Arc::clone(&engine), Duration::from_millis(50));
    let deadline = Instant::now() + Duration::from_secs(30);
    while sched.runs() == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(sched.runs() > 0, "scheduler wedged: no checkpoint after the I/O error");
    assert!(sched.has_failed(), "the first attempt must have hit the injected EIO");
    // Ingest is unaffected throughout.
    for chunk in pairs(1_000).chunks(256) {
        engine.observe_batch(chunk);
    }
    engine.quiesce();
    assert_eq!(engine.health(), Health::Healthy);
    sched.stop();
    drop(sched);
    engine.shutdown();
}
