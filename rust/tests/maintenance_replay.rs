//! Maintenance-as-data differentials (DESIGN.md §6): §II.C decay/repair
//! is WAL-logged and checkpoints are incremental, so
//!
//! * a follower replaying the leader's decay records is byte-identical to
//!   the leader at quiescence, across 1/2/8 shard layouts;
//! * crash recovery with decay records in the WAL equals a never-crashed
//!   reference — no conservatively-larger counts — with a kill-point
//!   sweep over decay/repair record boundaries;
//! * a base + delta checkpoint chain recovers to the same state as full
//!   snapshots of the same stream, compaction folds the chain back, and
//!   a corrupt newest delta degrades to the chain prefix + WAL replay.

use std::sync::Arc;
use std::time::Duration;

use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::config::{PersistSection, ServerConfig};
use mcprioq::coordinator::{Client, Engine, Server};
use mcprioq::persist::codec::WalOp;
use mcprioq::persist::wal::{self, ShardWal};
use mcprioq::persist::{open_engine, FsyncPolicy, IoHandle};
use mcprioq::replicate::start_follower;
use mcprioq::testutil::{Rng64, TempDir};

/// A skewed stream with frequent same-src runs (as the persist tests use).
fn stream(len: usize, seed: u64) -> Vec<(u64, u64)> {
    let mut rng = Rng64::new(seed);
    let mut out = Vec::with_capacity(len);
    let mut src = 0u64;
    for i in 0..len {
        if i % 4 == 0 {
            src = rng.next_below(48);
        }
        let u = rng.next_f64();
        out.push((src, ((u * u) * 96.0) as u64));
    }
    out
}

fn durable_config(dir: &std::path::Path, shards: usize) -> ServerConfig {
    ServerConfig {
        shards,
        queue_capacity: 4_096,
        persist: PersistSection {
            data_dir: dir.to_string_lossy().into_owned(),
            fsync: "never".into(),
            checkpoint_interval_ms: 0,
            ..PersistSection::default()
        },
        ..Default::default()
    }
}

fn apply_to_chain(chain: &McPrioQ, op: &WalOp) {
    match op {
        WalOp::Batch(batch) => {
            chain.observe_batch(batch);
        }
        WalOp::Decay { num, den } => {
            chain.decay_with(*num, *den);
        }
        WalOp::Repair => {
            chain.repair();
        }
    }
}

#[test]
fn follower_with_decay_matches_leader_across_layouts() {
    for shards in [1usize, 2, 8] {
        let ltmp = TempDir::new("mdecay-leader");
        let ftmp = TempDir::new("mdecay-follower");
        let (leader, _) = open_engine(&durable_config(ltmp.path(), shards), 2).unwrap();
        let server = Server::bind(Arc::clone(&leader), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let _lh = server.spawn();
        let follower =
            start_follower(durable_config(ftmp.path(), shards), 1, &addr).unwrap();

        // Interleave wire traffic and wire DECAYs — the follower sees them
        // only as WAL records.
        let mut client = Client::connect(&addr).unwrap();
        for (round, seed) in [0x1AD1u64, 0x1AD2, 0x1AD3].into_iter().enumerate() {
            let pairs = stream(6_000, seed + shards as u64);
            for chunk in pairs.chunks(997) {
                assert_eq!(client.observe_batch(chunk).unwrap(), chunk.len());
            }
            leader.quiesce();
            if round < 2 {
                leader.decay();
            }
        }
        leader.quiesce();
        let target = leader.stats().wal_last_seqs;
        assert!(
            follower.wait_caught_up(&target, Duration::from_secs(20)),
            "{shards} shards: follower stuck at {:?} (fault: {:?})",
            follower.state.applied_seqs(),
            follower.state.fault()
        );

        // The acceptance bar: with decay enabled and applied, the
        // follower's quiesced export is byte-identical to the leader's.
        assert_eq!(
            leader.export_quiesced(),
            follower.engine.export_quiesced(),
            "{shards} shards with decay"
        );
        let fstats = follower.engine.stats();
        assert_eq!(
            fstats.decays_per_shard,
            vec![2u64; shards],
            "{shards} shards: every shard replays both decay records"
        );

        follower.engine.shutdown();
        leader.shutdown();
    }
}

#[test]
fn crash_recovery_with_decay_matches_never_crashed_reference() {
    let tmp = TempDir::new("decay-recovery");
    let config = durable_config(tmp.path(), 2);
    let plain = ServerConfig { persist: PersistSection::default(), ..config.clone() };
    let reference_engine = Engine::new(&plain, 2);

    let (engine, _) = open_engine(&config, 2).unwrap();
    let mut checkpointed = false;
    for (round, seed) in [0xC4A1u64, 0xC4A2, 0xC4A3, 0xC4A4].into_iter().enumerate() {
        let pairs = stream(5_000, seed);
        for chunk in pairs.chunks(311) {
            assert_eq!(engine.observe_batch(chunk), chunk.len());
            reference_engine.observe_batch(chunk);
        }
        // Quiesce both so the decay lands at the same per-shard sequence
        // position in the durable engine's WAL and in the reference.
        engine.quiesce();
        reference_engine.quiesce();
        engine.decay();
        reference_engine.decay();
        if round == 1 {
            // Mid-stream checkpoint: later decays live only in the WAL,
            // and one decay is *behind* the snapshot (replayed via fold).
            engine.checkpoint().unwrap();
            checkpointed = true;
        }
    }
    assert!(checkpointed);
    engine.quiesce();
    reference_engine.quiesce();
    let reference = reference_engine.export();
    assert_eq!(engine.export(), reference, "pre-crash states must agree");
    engine.shutdown();
    drop(engine);

    // The old failure mode: recovery replayed observations onto pre-decay
    // counts and recovered conservatively-larger totals. With decay
    // records in the WAL the recovered model is *equal*, not larger.
    let (recovered, report) = open_engine(&config, 0).unwrap();
    assert!(report.replayed_maintenance > 0, "decay records must replay");
    assert_eq!(recovered.export(), reference);
    recovered.shutdown();
    reference_engine.shutdown();
}

#[test]
fn kill_point_sweep_over_decay_record_boundaries() {
    let tmp = TempDir::new("decay-killpoint");
    let dir = tmp.join("shard-0000");
    let mut wal = ShardWal::open(
        dir.clone(),
        IoHandle::std(),
        0,
        FsyncPolicy::Never,
        Duration::from_millis(50),
        1 << 20, // one segment: every cut lands in the same file
    )
    .unwrap();
    let mut rng = Rng64::new(0xDEC0);
    let mut ops: Vec<WalOp> = Vec::new();
    let mut boundaries = Vec::new(); // file length after each append
    for i in 0..40 {
        let op = if i % 5 == 4 {
            WalOp::Decay { num: 1, den: 2 }
        } else if i % 11 == 7 {
            WalOp::Repair
        } else {
            WalOp::Batch(
                (0..rng.next_below(6) + 1)
                    .map(|_| (rng.next_below(16), rng.next_below(16)))
                    .collect(),
            )
        };
        match &op {
            WalOp::Batch(batch) => {
                wal.append(batch).unwrap();
            }
            other => {
                wal.append_op(other).unwrap();
            }
        }
        ops.push(op);
        boundaries.push(wal.segment_len());
    }
    drop(wal);
    let seg_path = wal::scan_segments(&dir).unwrap().remove(0).path;
    let full = std::fs::read(&seg_path).unwrap();
    assert_eq!(*boundaries.last().unwrap() as usize, full.len());

    // Cut at every record boundary — decay and repair boundaries included
    // — and inside the next frame: recovery yields exactly the surviving
    // op prefix, torn iff mid-frame.
    let mut cuts: Vec<usize> = vec![0, 8];
    for &b in &boundaries {
        cuts.push(b as usize);
        cuts.push(b as usize + 3);
    }
    for cut in cuts {
        let cut = cut.min(full.len());
        let cut_dir = tmp.join(&format!("cut-{cut}"));
        std::fs::create_dir_all(&cut_dir).unwrap();
        std::fs::write(cut_dir.join(seg_path.file_name().unwrap()), &full[..cut]).unwrap();

        let survivors = boundaries.iter().filter(|&&b| b as usize <= cut).count();
        let recovered = McPrioQ::new(ChainConfig::default());
        let mut replayed = 0usize;
        let stats = wal::replay_dir(&cut_dir, 0, |_seq, op| {
            apply_to_chain(&recovered, &op);
            replayed += 1;
        })
        .unwrap();
        assert_eq!(replayed, survivors, "cut {cut}");
        let exact_boundary = cut == 8 || boundaries.iter().any(|&b| b as usize == cut);
        assert_eq!(stats.torn, !exact_boundary, "cut {cut}");

        let reference = McPrioQ::new(ChainConfig::default());
        for op in &ops[..survivors] {
            apply_to_chain(&reference, op);
        }
        assert_eq!(recovered.export(), reference.export(), "cut {cut}");
        std::fs::remove_dir_all(&cut_dir).unwrap();
    }
}

#[test]
fn delta_chain_recovery_matches_full_snapshots() {
    let tmp = TempDir::new("delta-chain");
    let full_tmp = TempDir::new("delta-chain-full");
    let mut config = durable_config(tmp.path(), 2);
    config.persist.delta_chain_max = 2;
    // High ratio: the sparse touch rounds below stay differential.
    config.persist.delta_dirty_ratio = 0.9;
    let mut full_config = durable_config(full_tmp.path(), 2);
    full_config.persist.delta_chain_max = 0; // every generation full

    let (engine, _) = open_engine(&config, 2).unwrap();
    let (full_engine, _) = open_engine(&full_config, 2).unwrap();
    let feed = |pairs: &[(u64, u64)]| {
        for chunk in pairs.chunks(503) {
            assert_eq!(engine.observe_batch(chunk), chunk.len());
            full_engine.observe_batch(chunk);
        }
        engine.quiesce();
        full_engine.quiesce();
    };

    // Base: the whole model, then two sparse-touch rounds → two deltas.
    feed(&stream(16_000, 0xDE17));
    let base = engine.checkpoint().unwrap();
    assert_eq!(base.kind, "full");
    full_engine.checkpoint().unwrap();

    let touch_a: Vec<(u64, u64)> = (0..6u64).map(|s| (s, s + 1)).collect();
    feed(&touch_a);
    let d1 = engine.checkpoint().unwrap();
    assert_eq!(d1.kind, "delta");
    assert!(
        d1.bytes < base.bytes / 4,
        "differential bytes must scale with the dirty set: {} vs full {}",
        d1.bytes,
        base.bytes
    );
    full_engine.checkpoint().unwrap();

    let touch_b: Vec<(u64, u64)> = (10..22u64).map(|s| (s, s + 2)).collect();
    feed(&touch_b);
    let d2 = engine.checkpoint().unwrap();
    assert_eq!(d2.kind, "delta");
    full_engine.checkpoint().unwrap();

    // Post-checkpoint tail lives only in the WAL.
    feed(&stream(2_000, 0xDE18));
    let reference = full_engine.export_quiesced();
    assert_eq!(engine.export_quiesced(), reference);

    // Chain length hit delta_chain_max = 2: the next generation compacts.
    let compacted = engine.checkpoint().unwrap();
    assert_eq!(compacted.kind, "full", "chain-length compaction");

    // One more sparse round, then crash: recovery folds full + delta.
    feed(&touch_a);
    let d3 = engine.checkpoint().unwrap();
    assert_eq!(d3.kind, "delta");
    feed(&stream(1_000, 0xDE19));
    full_engine.quiesce();
    let reference = full_engine.export_quiesced();
    assert_eq!(engine.export_quiesced(), reference);
    engine.shutdown();
    drop(engine);

    let (recovered, report) = open_engine(&config, 0).unwrap();
    assert_eq!(report.generation, d3.generation);
    assert_eq!(report.snapshot_deltas, 1, "one delta folded onto the compacted base");
    assert_eq!(recovered.export(), reference, "base+delta chain == full snapshots");
    recovered.shutdown();

    // Corrupt the newest delta: recovery degrades to the chain prefix
    // (the compacted full) + a longer WAL replay — same state, because
    // lag-one truncation kept the WAL reachable from the previous cuts.
    let delta_path = tmp
        .join("checkpoint")
        .join(format!("ckpt-{:06}.delta", d3.generation));
    let mut bytes = std::fs::read(&delta_path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&delta_path, &bytes).unwrap();
    let (recovered, report) = open_engine(&config, 0).unwrap();
    assert_eq!(report.generation, compacted.generation, "prefix fallback");
    assert_eq!(report.snapshot_deltas, 0);
    assert_eq!(recovered.export(), reference, "fallback + WAL replay equality");
    recovered.shutdown();
    full_engine.shutdown();
}
