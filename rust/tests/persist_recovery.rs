//! Durability differentials (DESIGN.md §4):
//!
//! * Round-trip property: `export → encode → decode → import → export` is
//!   byte-identical across 1/2/8 shard configs (and re-encoding the
//!   decoded snapshot reproduces the original bytes).
//! * Kill-point differential: a WAL cut at *every* record boundary (and
//!   inside frames) recovers exactly the surviving prefix — equal to a
//!   reference chain fed the same prefix — with torn tails flagged iff the
//!   cut is mid-frame.
//! * End-to-end engine recovery: checkpoint + WAL tail replay rebuilds an
//!   export identical to a never-crashed reference engine fed the same
//!   acked stream, torn final records tolerated, reopen idempotent.
//! * Shard-layout changes re-route the recovered data and bump the WAL
//!   epoch without losing a batch.
//! * `SAVE` over the wire checkpoints a live server; a restart serves the
//!   same model.

use std::sync::Arc;

use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::config::{PersistSection, ServerConfig};
use mcprioq::coordinator::{Client, Engine, Request, Response, Server};
use mcprioq::persist::wal::{self, ShardWal};
use mcprioq::persist::{codec, open_engine, FsyncPolicy, IoHandle};
use mcprioq::testutil::{Rng64, TempDir};

/// A skewed stream with frequent same-src runs (as the batch tests use).
fn stream(len: usize, seed: u64) -> Vec<(u64, u64)> {
    let mut rng = Rng64::new(seed);
    let mut out = Vec::with_capacity(len);
    let mut src = 0u64;
    for i in 0..len {
        if i % 4 == 0 {
            src = rng.next_below(48);
        }
        let u = rng.next_f64();
        out.push((src, ((u * u) * 96.0) as u64));
    }
    out
}

fn durable_config(dir: &std::path::Path, shards: usize) -> ServerConfig {
    ServerConfig {
        shards,
        queue_capacity: 4_096,
        persist: PersistSection {
            data_dir: dir.to_string_lossy().into_owned(),
            fsync: "never".into(),
            // Explicit checkpoints only: the tests control the cut points.
            checkpoint_interval_ms: 0,
            ..PersistSection::default()
        },
        ..Default::default()
    }
}

#[test]
fn snapshot_roundtrip_identical_across_shard_configs() {
    let pairs = stream(30_000, 0xBEEF);
    let mut reference: Option<codec::Export> = None;
    for shards in [1usize, 2, 8] {
        let config = ServerConfig { shards, queue_capacity: 4_096, ..Default::default() };
        let engine = Engine::new(&config, 0);
        for chunk in pairs.chunks(499) {
            engine.observe_batch_direct(chunk);
        }
        let exported = engine.export();
        // Shards hold disjoint srcs: the merged export is shard-count
        // independent, so one reference covers all three configs.
        match &reference {
            Some(r) => assert_eq!(r, &exported, "{shards} shards"),
            None => reference = Some(exported.clone()),
        }

        // export → encode → decode is lossless and re-encodes identically.
        let cuts: Vec<u64> = (0..shards as u64).collect();
        let bytes = codec::encode_snapshot(1, &cuts, &exported);
        let (epoch, got_cuts, decoded) = codec::decode_snapshot(&bytes).unwrap();
        assert_eq!((epoch, &got_cuts, &decoded), (1, &cuts, &exported), "{shards} shards");
        assert_eq!(codec::encode_snapshot(epoch, &got_cuts, &decoded), bytes);

        // decode → import → export reproduces the model byte-for-byte,
        // into an engine of the same shape and into a bare chain.
        let imported = Engine::new(&config, 0);
        imported.import_snapshot(&decoded);
        assert_eq!(imported.export(), exported, "{shards} shards import");
        let chain = McPrioQ::import(ChainConfig::default(), &decoded);
        assert_eq!(chain.export(), exported, "{shards} shards chain import");
        engine.shutdown();
        imported.shutdown();
    }
}

#[test]
fn kill_point_recovery_matches_surviving_prefix() {
    let tmp = TempDir::new("killpoint");
    let dir = tmp.join("shard-0000");
    let mut wal = ShardWal::open(
        dir.clone(),
        IoHandle::std(),
        0,
        FsyncPolicy::Never,
        std::time::Duration::from_millis(50),
        1 << 20, // one segment: every cut lands in the same file
    )
    .unwrap();
    let mut rng = Rng64::new(0xCAFE);
    let mut batches: Vec<Vec<(u64, u64)>> = Vec::new();
    let mut boundaries = Vec::new(); // file length after each append
    for _ in 0..40 {
        let batch: Vec<(u64, u64)> = (0..rng.next_below(6) + 1)
            .map(|_| (rng.next_below(16), rng.next_below(16)))
            .collect();
        wal.append(&batch).unwrap();
        batches.push(batch);
        boundaries.push(wal.segment_len());
    }
    drop(wal);
    let seg_path = wal::scan_segments(&dir).unwrap().remove(0).path;
    let full = std::fs::read(&seg_path).unwrap();
    assert_eq!(*boundaries.last().unwrap() as usize, full.len());

    // Cut the log at every record boundary and at offsets inside the next
    // frame; recovery must yield exactly the batches wholly before the cut.
    let mut cuts: Vec<usize> = vec![0, 3, 8, 11];
    for &b in &boundaries {
        cuts.push(b as usize);
        cuts.push(b as usize + 1);
        cuts.push(b as usize + 5);
    }
    for cut in cuts {
        let cut = cut.min(full.len());
        let cut_dir = tmp.join(&format!("cut-{cut}"));
        std::fs::create_dir_all(&cut_dir).unwrap();
        std::fs::write(cut_dir.join(seg_path.file_name().unwrap()), &full[..cut]).unwrap();

        let survivors = boundaries.iter().filter(|&&b| b as usize <= cut).count();
        let recovered = McPrioQ::new(ChainConfig::default());
        let stats = wal::replay_dir(&cut_dir, 0, |_seq, op| match op {
            codec::WalOp::Batch(batch) => {
                recovered.observe_batch(&batch);
            }
            other => panic!("unexpected record {other:?}"),
        })
        .unwrap();
        assert_eq!(stats.batches as usize, survivors, "cut {cut}");
        let exact_boundary = cut == 8 || boundaries.iter().any(|&b| b as usize == cut);
        assert_eq!(stats.torn, !exact_boundary, "cut {cut}");

        let reference = McPrioQ::new(ChainConfig::default());
        for batch in &batches[..survivors] {
            reference.observe_batch(batch);
        }
        assert_eq!(recovered.export(), reference.export(), "cut {cut}");
        std::fs::remove_dir_all(&cut_dir).unwrap();
    }
}

#[test]
fn engine_recovers_acked_stream_after_crash() {
    let tmp = TempDir::new("engine-recovery");
    let config = durable_config(tmp.path(), 2);
    let pairs = stream(24_000, 0xD00D);
    let (half_a, half_b) = pairs.split_at(pairs.len() / 2);

    // A never-persisted reference engine fed the same acked stream.
    let plain = ServerConfig { persist: PersistSection::default(), ..config.clone() };
    let reference_engine = Engine::new(&plain, 2);

    let (engine, report) = open_engine(&config, 2).unwrap();
    assert_eq!(report.generation, 0);
    assert_eq!(report.replayed_batches, 0);
    for chunk in half_a.chunks(311) {
        assert_eq!(engine.observe_batch(chunk), chunk.len());
        reference_engine.observe_batch(chunk);
    }
    // Mid-stream checkpoint: the tail after this lives only in the WAL.
    let summary = engine.checkpoint().unwrap();
    assert_eq!(summary.generation, 1);
    for chunk in half_b.chunks(311) {
        assert_eq!(engine.observe_batch(chunk), chunk.len());
        reference_engine.observe_batch(chunk);
    }
    engine.quiesce();
    reference_engine.quiesce();
    let reference = reference_engine.export();
    assert_eq!(engine.export(), reference);
    let wal_bytes = engine.stats().wal_bytes;
    assert!(wal_bytes > 0, "tail batches must be in the WAL");
    // "Crash": no shutdown checkpoint, just drop (workers drain + join).
    engine.shutdown();
    drop(engine);

    // Recover: checkpoint + WAL tail must rebuild the exact model.
    let (recovered, report) = open_engine(&config, 2).unwrap();
    assert_eq!(report.generation, 1);
    assert!(report.snapshot_nodes > 0);
    assert!(report.replayed_batches > 0, "the post-checkpoint tail must replay");
    assert_eq!(recovered.export(), reference);
    assert_eq!(recovered.stats().recovered_batches, report.replayed_batches);
    recovered.shutdown();
    drop(recovered);

    // Reopen again with no new writes: idempotent (cuts + seqs respected).
    let (again, report2) = open_engine(&config, 0).unwrap();
    assert_eq!(report2.replayed_batches, report.replayed_batches);
    assert_eq!(again.export(), reference);
    again.shutdown();
    drop(again);

    // Torn final record: garbage on the newest segment is tolerated.
    let epoch_dir = tmp.join("wal").join("e1");
    let mut appended = false;
    for shard in std::fs::read_dir(&epoch_dir).unwrap().flatten() {
        if let Some(seg) = wal::scan_segments(&shard.path()).unwrap().last() {
            let mut bytes = std::fs::read(&seg.path).unwrap();
            bytes.extend_from_slice(&[0x5A; 11]);
            std::fs::write(&seg.path, bytes).unwrap();
            appended = true;
            break;
        }
    }
    assert!(appended, "expected at least one WAL segment");
    let (torn, report3) = open_engine(&config, 0).unwrap();
    assert!(report3.torn_tails >= 1);
    assert_eq!(torn.export(), reference);
    torn.shutdown();
    reference_engine.shutdown();
}

#[test]
fn shard_layout_change_rebuckets_and_bumps_epoch() {
    let tmp = TempDir::new("layout-change");
    let pairs = stream(12_000, 0xFACE);

    let config2 = durable_config(tmp.path(), 2);
    let (engine, _) = open_engine(&config2, 2).unwrap();
    for chunk in pairs.chunks(257) {
        assert_eq!(engine.observe_batch(chunk), chunk.len());
    }
    engine.quiesce();
    let reference = engine.export();
    engine.shutdown();
    drop(engine);

    // Restart with 3 shards: recovery re-routes, bumps the epoch, and
    // immediately checkpoints under the new layout.
    let config3 = durable_config(tmp.path(), 3);
    let (engine, report) = open_engine(&config3, 2).unwrap();
    assert!(report.layout_changed);
    assert_eq!(report.epoch, 2);
    assert_eq!(engine.export(), reference);
    assert!(!tmp.join("wal").join("e1").exists(), "old epoch swept");
    engine.shutdown();
    drop(engine);

    // And the new layout keeps recovering cleanly.
    let (engine, report) = open_engine(&config3, 0).unwrap();
    assert!(!report.layout_changed);
    assert_eq!(report.epoch, 2);
    assert_eq!(engine.export(), reference);
    engine.shutdown();
}

/// The `CKPT_MARK` sidecar keeps checkpoints *differential across a
/// restart*: before it, recovery re-armed the dirty floor at 0 and the
/// first post-restart checkpoint always degraded to a full snapshot.
#[test]
fn checkpoints_stay_incremental_across_restart() {
    let tmp = TempDir::new("ckpt-mark");
    let config = durable_config(tmp.path(), 2);
    let pairs = stream(12_000, 0xABCD);

    let (engine, _) = open_engine(&config, 2).unwrap();
    for chunk in pairs.chunks(311) {
        assert_eq!(engine.observe_batch(chunk), chunk.len());
    }
    engine.quiesce();
    assert_eq!(engine.checkpoint().unwrap().kind, "full"); // gen 1: the base
    // A few srcs dirty (well under the compaction ratio): gen 2 is a delta.
    assert_eq!(engine.observe_batch(&[(7, 8), (7, 9)]), 2);
    engine.quiesce();
    assert_eq!(engine.checkpoint().unwrap().kind, "delta"); // gen 2
    let total_nodes = engine.node_count();
    let reference = engine.export();
    engine.shutdown();
    drop(engine);

    // Restart, touch a handful of srcs, checkpoint: still a delta, and a
    // small one — only the post-restart writes are in the payload.
    let (engine, report) = open_engine(&config, 2).unwrap();
    assert_eq!(report.generation, 2);
    assert_eq!(engine.export(), reference);
    assert_eq!(engine.observe_batch(&[(1, 2), (1, 2), (3, 4)]), 3);
    engine.quiesce();
    let summary = engine.checkpoint().unwrap();
    assert_eq!(summary.kind, "delta", "post-restart checkpoint degraded to full");
    assert_eq!(summary.generation, 3);
    assert!(
        summary.nodes < total_nodes / 2,
        "delta payload covers {} of {} nodes — not incremental",
        summary.nodes,
        total_nodes
    );
    let reference = engine.export();
    engine.shutdown();
    drop(engine);

    // And the chain (base + deltas spanning the restart) still recovers.
    let (engine, report) = open_engine(&config, 0).unwrap();
    assert_eq!(report.generation, 3);
    assert_eq!(engine.export(), reference);
    engine.shutdown();
}

#[test]
fn save_over_the_wire_then_restart_serves_same_model() {
    let tmp = TempDir::new("wire-save");
    let config = durable_config(tmp.path(), 2);
    let (engine, _) = open_engine(&config, 2).unwrap();
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let handle = server.spawn();

    let mut client = Client::connect(addr).unwrap();
    let pairs: Vec<(u64, u64)> = stream(5_000, 0x5AFE);
    client.observe_batch(&pairs).unwrap();
    engine.quiesce();
    let detail = client.save().unwrap();
    assert!(detail.contains("gen=1"), "{detail}");
    // Post-SAVE tail: survives via the WAL, not the checkpoint.
    client.observe_batch(&[(1, 2), (1, 2), (1, 3)]).unwrap();
    engine.quiesce();
    let reference = engine.export();
    let topk_before = client.topk(1, 3).unwrap();
    let stats = client.stats().unwrap();
    assert!(stats.contains("wal_bytes="), "{stats}");
    assert!(stats.contains("ckpt_age="), "{stats}");
    assert!(stats.contains("recovered_batches=0"), "{stats}");
    drop(handle);
    engine.shutdown();
    drop(engine);

    let (engine, report) = open_engine(&config, 2).unwrap();
    assert_eq!(report.generation, 1);
    assert!(report.replayed_batches > 0);
    assert_eq!(engine.export(), reference);
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let _handle = server.spawn();
    let mut client = Client::connect(addr).unwrap();
    assert_eq!(client.topk(1, 3).unwrap(), topk_before);
    let stats = client.stats().unwrap();
    assert!(
        stats.contains(&format!("recovered_batches={}", report.replayed_batches)),
        "{stats}"
    );
    engine.shutdown();
}

#[test]
fn save_without_data_dir_is_a_clean_error() {
    let engine = Engine::new(&ServerConfig { shards: 1, ..Default::default() }, 1);
    assert!(engine.checkpoint().is_err());
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let _handle = server.spawn();
    let mut client = Client::connect(addr).unwrap();
    match client.request(&Request::Save).unwrap() {
        Response::Err(e) => assert!(e.contains("not enabled"), "{e}"),
        other => panic!("expected ERR, got {other:?}"),
    }
    engine.shutdown();
}
