#!/usr/bin/env python3
"""Unsafe-code audit gate (DESIGN.md § Concurrency verification).

Statically enforces the crate's two unsafe-code rules over rust/ and
examples/ (vendor/ is third-party and exempt):

1. Every `unsafe` occurrence is justified where it appears:
   - `unsafe { ... }` blocks and `unsafe impl` items need a `// SAFETY:`
     comment on the same line or in the contiguous comment block
     immediately above;
   - `unsafe fn` declarations need a `# Safety` section in their doc
     comment (the caller-facing contract; their *bodies* get no blanket
     license — `#![deny(unsafe_op_in_unsafe_fn)]` in lib.rs forces inner
     blocks, which rule 1 then covers individually).

2. The sync facade is the only door to atomics and to loom:
   `std::sync::atomic` / `core::sync::atomic` may appear only in
   rust/src/sync/shim.rs, and `loom::` only there and in the loom model
   harness rust/tests/loom_models.rs. Everything else must import from
   `crate::sync::shim` (or `mcprioq::sync::shim` outside the crate), so
   `--cfg loom` builds model the real synchronization, not a bypass.

Comment text, strings, and char literals are stripped before keyword
matching, so prose like "no unsafe" or a quoted "std::sync::atomic" never
trips the gate. Exit status is non-zero iff violations are found; each is
reported as file:line: message.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# Directories scanned for .rs files. vendor/ is deliberately absent.
SCAN_ROOTS = ["rust", "examples"]

SHIM = "rust/src/sync/shim.rs"
LOOM_HARNESS = "rust/tests/loom_models.rs"

ATOMIC_RE = re.compile(r"\b(?:std|core)::sync::atomic\b")
LOOM_RE = re.compile(r"\bloom::")
UNSAFE_RE = re.compile(r"\bunsafe\b")


def strip_code(text: str) -> list[str]:
    """Return the file's lines with comments, strings, and char literals
    blanked out (replaced by spaces, preserving line structure)."""
    out = []
    i = 0
    n = len(text)
    state = "code"  # code | line_comment | block_comment | string | rawstring | char
    depth = 0  # nested block comments
    hashes = 0  # raw string delimiter
    while i < n:
        c = text[i]
        nxt = text[i + 1] if i + 1 < n else ""
        if state == "code":
            if c == "/" and nxt == "/":
                state = "line_comment"
                out.append("  ")
                i += 2
                continue
            if c == "/" and nxt == "*":
                state = "block_comment"
                depth = 1
                out.append("  ")
                i += 2
                continue
            if c == '"':
                state = "string"
                out.append(" ")
                i += 1
                continue
            m = re.match(r"r(#*)\"", text[i:])
            if m and (i == 0 or not (text[i - 1].isalnum() or text[i - 1] == "_")):
                state = "rawstring"
                hashes = len(m.group(1))
                out.append(" " * len(m.group(0)))
                i += len(m.group(0))
                continue
            if c == "'":
                # Lifetime ('a) vs char literal ('x'): a lifetime is never
                # closed by a quote within a few chars; chars are 'x' or
                # an escape like '\n' / '\u{..}'.
                m = re.match(r"'(\\[^']*|[^'\\])'", text[i:])
                if m:
                    out.append(" " * len(m.group(0)))
                    i += len(m.group(0))
                    continue
            out.append(c)
            i += 1
        elif state == "line_comment":
            if c == "\n":
                state = "code"
                out.append(c)
            else:
                out.append(" ")
            i += 1
        elif state == "block_comment":
            if c == "/" and nxt == "*":
                depth += 1
                out.append("  ")
                i += 2
            elif c == "*" and nxt == "/":
                depth -= 1
                out.append("  ")
                i += 2
                if depth == 0:
                    state = "code"
            else:
                out.append(c if c == "\n" else " ")
                i += 1
        elif state == "string":
            if c == "\\":
                out.append("  ")
                i += 2
            elif c == '"':
                state = "code"
                out.append(" ")
                i += 1
            else:
                out.append(c if c == "\n" else " ")
                i += 1
        elif state == "rawstring":
            if c == '"' and text[i + 1 : i + 1 + hashes] == "#" * hashes:
                state = "code"
                out.append(" " * (1 + hashes))
                i += 1 + hashes
            else:
                out.append(c if c == "\n" else " ")
                i += 1
    return "".join(out).split("\n")


def is_comment_or_attr(line: str) -> bool:
    s = line.strip()
    return s.startswith("//") or s.startswith("#[") or s.startswith("#!")


def has_safety_comment(raw: list[str], lineno: int, before_col: int) -> bool:
    """SAFETY: on the unsafe's own line (before the keyword) or anywhere in
    the contiguous comment/attribute block above it."""
    if "SAFETY:" in raw[lineno][:before_col]:
        return True
    i = lineno - 1
    while i >= 0 and is_comment_or_attr(raw[i]):
        if "SAFETY:" in raw[i]:
            return True
        i -= 1
    return False


def has_safety_doc(raw: list[str], lineno: int) -> bool:
    """`# Safety` section in the doc/attribute block above an unsafe fn
    (also accepts a `// SAFETY:` comment for private helpers)."""
    i = lineno - 1
    while i >= 0 and is_comment_or_attr(raw[i]):
        if "# Safety" in raw[i] or "SAFETY:" in raw[i]:
            return True
        i -= 1
    return False


def audit_file(path: Path, rel: str) -> list[str]:
    text = path.read_text()
    raw = text.split("\n")
    code = strip_code(text)
    problems = []

    for lineno, line in enumerate(code):
        if rel != SHIM and ATOMIC_RE.search(line):
            problems.append(
                f"{rel}:{lineno + 1}: bare atomic import/path (use crate::sync::shim)"
            )
        if rel not in (SHIM, LOOM_HARNESS) and LOOM_RE.search(line):
            problems.append(
                f"{rel}:{lineno + 1}: direct loom reference outside the sync facade"
            )

        for m in UNSAFE_RE.finditer(line):
            after = line[m.end() :].lstrip()
            rest = after if after else next(
                (code[j].lstrip() for j in range(lineno + 1, len(code)) if code[j].strip()),
                "",
            )
            if rest.startswith("fn"):
                if not has_safety_doc(raw, lineno):
                    problems.append(
                        f"{rel}:{lineno + 1}: unsafe fn without a `# Safety` doc section"
                    )
            elif rest.startswith("trait") or rest.startswith("impl"):
                if not has_safety_comment(raw, lineno, m.start()):
                    problems.append(
                        f"{rel}:{lineno + 1}: unsafe impl/trait without a `// SAFETY:` comment"
                    )
            else:
                # An unsafe block (incl. `let x = unsafe { ... }`).
                if not has_safety_comment(raw, lineno, m.start()):
                    problems.append(
                        f"{rel}:{lineno + 1}: unsafe block without a `// SAFETY:` comment"
                    )
    return problems


def main() -> int:
    problems = []
    scanned = 0
    for root in SCAN_ROOTS:
        for path in sorted((REPO / root).rglob("*.rs")):
            rel = path.relative_to(REPO).as_posix()
            scanned += 1
            problems.extend(audit_file(path, rel))
    if problems:
        for p in problems:
            print(p)
        print(f"\nunsafe_audit: {len(problems)} violation(s) in {scanned} files")
        return 1
    print(f"unsafe_audit: OK ({scanned} files clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
