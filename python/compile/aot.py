"""AOT compile path: lower the Layer-2 JAX entry points to HLO *text*
artifacts the rust runtime loads via the `xla` crate.

HLO text — NOT `lowered.compile()` / serialized protos: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which the published xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once via `make artifacts`; python never appears on the request path.

Outputs, per (n, b, k) variant in VARIANTS:
    artifacts/dense_infer_n{n}_b{b}_k{k}.hlo.txt
    artifacts/dense_update_n{n}_b{b}.hlo.txt
    artifacts/dense_decay_n{n}.hlo.txt
    artifacts/manifest.txt   (one line per artifact: kind n b k filename)
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import decay_fn, infer_fn, update_fn

# (n, b, k) variants compiled ahead of time. n is the dense node capacity;
# rust picks the smallest variant that fits the live graph (E6 sweeps all).
VARIANTS = [
    (64, 8, 8),
    (256, 8, 16),
    (1024, 8, 16),
]


def to_hlo_text(lowered, return_tuple) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format).

    `return_tuple=False` is used for the single-output update/decay entry
    points: the PJRT result is then a plain array buffer that rust feeds
    straight back as the next call's `counts` argument, keeping the dense
    state resident on the device with zero host round-trips.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def lower_to_file(fn, args, path, return_tuple=True):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered, return_tuple)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def build(outdir):
    os.makedirs(outdir, exist_ok=True)
    manifest = []
    for n, b, k in VARIANTS:
        fn, args = infer_fn(n, b, k)
        name = f"dense_infer_n{n}_b{b}_k{k}.hlo.txt"
        size = lower_to_file(fn, args, os.path.join(outdir, name))
        manifest.append(f"infer {n} {b} {k} {name}")
        print(f"  {name}: {size} chars")

        fn, args = update_fn(n, b)
        name = f"dense_update_n{n}_b{b}.hlo.txt"
        size = lower_to_file(fn, args, os.path.join(outdir, name), return_tuple=False)
        manifest.append(f"update {n} {b} 0 {name}")
        print(f"  {name}: {size} chars")

        fn, args = decay_fn(n)
        name = f"dense_decay_n{n}.hlo.txt"
        size = lower_to_file(fn, args, os.path.join(outdir, name), return_tuple=False)
        manifest.append(f"decay {n} 0 0 {name}")
        print(f"  {name}: {size} chars")

    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts + manifest to {outdir}")


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
