"""Layer-1 Pallas kernel: batched row-normalize + top-k + cumulative
probability — the compute hot-spot of the dense markov-chain engine.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets
CPUs, so there is no GPU kernel to port; this kernel implements the *dense
comparator* the introduction motivates against, designed TPU-natively:

* BlockSpec tiles `block_b` query rows into VMEM per grid step; the row
  length `n` stays resident (n <= 4096 rows of f32 = 16 KiB/row, well
  under the ~16 MiB VMEM budget at the shapes we compile).
* Selection is k rounds of (argmax, mask) over the row block — pure VPU
  element-wise/reduction work with NO data-dependent control flow, which
  is what the TPU vector unit wants. A sort network would be k·log²n
  comparators for the same result; the k·n scan is memory-bound and
  saturates the same roofline. The MXU is deliberately idle: there is no
  contraction in this op.
* Everything is f32: transition counts are integers < 2^24, so f32 is
  exact (the rust engine asserts this bound on ingest).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the rust
runtime loads. On a real TPU the same `pallas_call` compiles natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = jnp.float32(-1.0)  # probabilities live in [0, 1]; -1 masks a slot


def _kernel(counts_ref, ids_ref, probs_ref, cum_ref, *, k):
    """One grid step: a [block_b, n] tile of gathered count rows."""
    counts = counts_ref[...]
    totals = jnp.sum(counts, axis=-1, keepdims=True)
    probs = jnp.where(totals > 0, counts / jnp.maximum(totals, 1.0), 0.0)

    def body(i, carry):
        probs, cum = carry
        idx = jnp.argmax(probs, axis=-1)  # first max == lowest-index tie
        p = jnp.take_along_axis(probs, idx[:, None], axis=-1)[:, 0]
        cum = cum + p
        ids_ref[:, i] = idx.astype(jnp.int32)
        probs_ref[:, i] = p
        cum_ref[:, i] = cum
        # Mask the selected column out of contention.
        onehot = jax.nn.one_hot(idx, probs.shape[-1], dtype=probs.dtype)
        probs = probs - (probs + 1.0) * onehot  # selected slot -> -1
        return probs, cum

    b = counts.shape[0]
    jax.lax.fori_loop(0, k, body, (probs, jnp.zeros((b,), jnp.float32)), unroll=False)


def topk_cumprob(counts, k, block_b=8):
    """Pallas dense inference over gathered rows.

    Args:
      counts: f32[b, n]; b must be a multiple of block_b (the AOT wrapper
        pads queries, so compiled artifacts always satisfy this).
      k: static item count.
      block_b: rows per grid step (VMEM tile height).

    Returns (ids i32[b, k], probs f32[b, k], cum f32[b, k]).
    """
    b, n = counts.shape
    assert b % block_b == 0, f"batch {b} not a multiple of block {block_b}"
    assert 1 <= k <= n, f"k={k} out of range for n={n}"
    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
        ],
        interpret=True,
    )(counts)
