"""Layer-1 Pallas kernel: model decay (§II.C) for the dense engine —
floor-halve every counter, tiled through VMEM.

Element-wise and embarrassingly parallel: the BlockSpec streams row tiles
HBM -> VMEM -> HBM; the arithmetic is two VPU ops per element, so the op is
pure memory bandwidth (the roofline note in DESIGN.md §Perf).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(counts_ref, out_ref):
    out_ref[...] = jnp.floor(counts_ref[...] * 0.5)


def decay(counts, block_rows=64):
    """Floor-halve a [n, n] counts matrix (integer decay semantics)."""
    n, m = counts.shape
    block = min(block_rows, n)
    assert n % block == 0, f"rows {n} not a multiple of block {block}"
    return pl.pallas_call(
        _kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(counts)
