"""Pure-jnp oracle for the dense markov-chain engine.

This is the correctness ground truth (invariant P7): the Pallas kernels in
`topk_cumprob.py` / `decay.py` must match these functions exactly on ids
and to float tolerance on probabilities, across the shape/dtype sweep in
python/tests/.

Tie-breaking contract: equal probabilities resolve to the LOWEST dst index
first. Both the iterative-argmax kernel (argmax returns the first maximum)
and the stable descending sort here honour it, so id comparisons are exact.
"""

import jax.numpy as jnp


def normalize_rows(counts):
    """Row-normalize a counts matrix into transition probabilities.

    Zero rows (no observations out of a node) normalize to all-zero
    probabilities rather than NaN.
    """
    totals = counts.sum(axis=-1, keepdims=True)
    return jnp.where(totals > 0, counts / jnp.maximum(totals, 1), 0.0)


def topk_cumprob(counts, k):
    """Reference dense inference.

    Args:
      counts: f32[b, n] gathered transition-count rows.
      k: static number of items to return.

    Returns:
      ids:   i32[b, k] destination indices, descending probability,
             ties broken toward the lower index.
      probs: f32[b, k] their probabilities.
      cum:   f32[b, k] inclusive cumulative probabilities (the quantity the
             threshold test in rust compares against t).
    """
    probs_full = normalize_rows(counts)
    # Stable argsort of -p gives descending order with lowest-index-first
    # ties — identical to k successive argmaxes.
    order = jnp.argsort(-probs_full, axis=-1, stable=True)
    ids = order[:, :k].astype(jnp.int32)
    probs = jnp.take_along_axis(probs_full, order[:, :k], axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    return ids, probs, cum


def decay(counts):
    """Reference decay: floor-halve every counter (integer semantics, to
    match the rust sparse engine's `c / 2`)."""
    return jnp.floor(counts * 0.5)


def update(counts, srcs, dsts):
    """Reference batched update: scatter-add 1 to each (src, dst) pair."""
    return counts.at[srcs, dsts].add(1.0)
