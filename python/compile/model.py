"""Layer-2 JAX model: the dense markov-chain engine.

Composes the Layer-1 Pallas kernels into the three jitted entry points the
rust runtime executes via PJRT:

* `dense_infer(counts, queries)` — gather query rows, then the Pallas
  top-k/cum-prob kernel. The *whole* inference (gather + normalize +
  select) lowers into one HLO module, so the rust hot path is a single
  `execute` per batch.
* `dense_update(counts, srcs, dsts)` — batched scatter-add of observed
  transitions. Functional: returns the new counts buffer (the rust engine
  keeps the live buffer on the PJRT device and feeds it back — no host
  round-trip; see rust/src/runtime/).
* `dense_decay(counts)` — §II.C decay through the Pallas halving kernel.

The contrast this engine exists for (experiment E6): every update/decay
touches O(n²) dense state and inference pays O(n) per row regardless of
sparsity, whereas MCPrioQ pays O(1) per update and O(CDF⁻¹(t)) per query.
"""

import jax
import jax.numpy as jnp

from .kernels.decay import decay as decay_kernel
from .kernels.topk_cumprob import topk_cumprob


def dense_infer(counts, queries, *, k, block_b=8):
    """Dense inference: top-k next nodes for each queried src row.

    Args:
      counts: f32[n, n] transition-count matrix.
      queries: i32[b] src node indices (b a multiple of block_b; rust pads
        with repeats and ignores the padded outputs).
      k: static items per query.

    Returns (ids i32[b,k], probs f32[b,k], cum f32[b,k], totals f32[b]).
    """
    rows = jnp.take(counts, queries, axis=0)  # [b, n] gather
    ids, probs, cum = topk_cumprob(rows, k, block_b=block_b)
    totals = rows.sum(axis=-1)  # [b] per-src transition mass
    return ids, probs, cum, totals


def dense_update(counts, srcs, dsts):
    """Scatter-add one observation per (src, dst) pair. Returns new counts."""
    return counts.at[srcs, dsts].add(1.0)


def dense_decay(counts):
    """Floor-halve all counters (matches sparse integer decay)."""
    return decay_kernel(counts)


def infer_fn(n, b, k):
    """The jittable inference entry point for AOT lowering."""

    def fn(counts, queries):
        ids, probs, cum, totals = dense_infer(counts, queries, k=k)
        return (ids, probs, cum, totals)

    return fn, (
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    )


def update_fn(n, b):
    """The jittable update entry point for AOT lowering."""

    def fn(counts, srcs, dsts):
        return dense_update(counts, srcs, dsts)

    return fn, (
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    )


def decay_fn(n):
    """The jittable decay entry point for AOT lowering."""

    def fn(counts):
        return dense_decay(counts)

    return fn, (jax.ShapeDtypeStruct((n, n), jnp.float32),)
