"""Pallas kernel vs pure-jnp oracle (invariant P7) — the core correctness
signal of the accelerator layers, including hypothesis sweeps over shapes,
values and degenerate inputs."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.decay import decay as pallas_decay
from compile.kernels.topk_cumprob import topk_cumprob


def make_counts(rng, b, n, max_count=50, zero_rows=0):
    counts = rng.integers(0, max_count, size=(b, n)).astype(np.float32)
    for r in range(zero_rows):
        counts[r % b] = 0.0
    return counts


def assert_matches_ref(counts, k, block_b=8):
    ids, probs, cum = topk_cumprob(jnp.array(counts), k, block_b=block_b)
    rid, rp, rc = ref.topk_cumprob(jnp.array(counts), k)
    np.testing.assert_array_equal(np.array(ids), np.array(rid))
    np.testing.assert_allclose(np.array(probs), np.array(rp), atol=1e-6)
    np.testing.assert_allclose(np.array(cum), np.array(rc), atol=1e-6)


class TestTopkCumprob:
    def test_basic(self):
        rng = np.random.default_rng(0)
        assert_matches_ref(make_counts(rng, 8, 64), k=8)

    def test_zero_rows_give_zero_probs(self):
        counts = np.zeros((8, 32), np.float32)
        ids, probs, cum = topk_cumprob(jnp.array(counts), 4)
        assert np.all(np.array(probs) == 0.0)
        assert np.all(np.array(cum) == 0.0)
        # Ties at p=0 resolve to lowest indices: 0..k-1.
        np.testing.assert_array_equal(np.array(ids), np.tile(np.arange(4), (8, 1)))

    def test_single_hot_item(self):
        counts = np.zeros((8, 16), np.float32)
        counts[:, 5] = 7.0
        ids, probs, cum = topk_cumprob(jnp.array(counts), 3)
        assert np.all(np.array(ids)[:, 0] == 5)
        np.testing.assert_allclose(np.array(probs)[:, 0], 1.0)
        np.testing.assert_allclose(np.array(cum)[:, 1:], 1.0, atol=1e-6)

    def test_k_equals_n(self):
        rng = np.random.default_rng(1)
        counts = make_counts(rng, 8, 16)
        assert_matches_ref(counts, k=16)
        # Full scan must cover probability 1 for nonzero rows.
        _, _, cum = topk_cumprob(jnp.array(counts), 16)
        np.testing.assert_allclose(np.array(cum)[:, -1], 1.0, atol=1e-5)

    def test_tie_breaking_prefers_low_index(self):
        counts = np.full((8, 12), 3.0, np.float32)
        ids, _, _ = topk_cumprob(jnp.array(counts), 5)
        np.testing.assert_array_equal(np.array(ids), np.tile(np.arange(5), (8, 1)))

    def test_multiple_grid_blocks(self):
        rng = np.random.default_rng(2)
        # 32 rows with block_b=8 -> 4 grid steps.
        assert_matches_ref(make_counts(rng, 32, 64, zero_rows=3), k=8)

    def test_block_b_one(self):
        rng = np.random.default_rng(3)
        assert_matches_ref(make_counts(rng, 4, 32), k=4, block_b=1)

    def test_cumulative_is_monotone(self):
        rng = np.random.default_rng(4)
        counts = make_counts(rng, 8, 128)
        _, _, cum = topk_cumprob(jnp.array(counts), 16)
        cum = np.array(cum)
        assert np.all(np.diff(cum, axis=1) >= -1e-7)
        assert np.all(cum <= 1.0 + 1e-6)

    def test_rejects_bad_shapes(self):
        counts = np.zeros((7, 16), np.float32)  # 7 % 8 != 0
        with pytest.raises(AssertionError):
            topk_cumprob(jnp.array(counts), 4)
        with pytest.raises(AssertionError):
            topk_cumprob(jnp.zeros((8, 16), jnp.float32), 17)  # k > n

    @settings(max_examples=40, deadline=None)
    @given(
        b_blocks=st.integers(1, 3),
        n=st.sampled_from([8, 16, 33, 64, 100]),
        k_frac=st.floats(0.1, 1.0),
        max_count=st.sampled_from([1, 2, 50, 1000, 2**20]),
        seed=st.integers(0, 2**32 - 1),
    )
    def test_hypothesis_sweep(self, b_blocks, n, k_frac, max_count, seed):
        rng = np.random.default_rng(seed)
        b = 8 * b_blocks
        k = max(1, int(n * k_frac))
        counts = make_counts(rng, b, n, max_count=max_count, zero_rows=seed % 3)
        assert_matches_ref(counts, k=k)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1))
    def test_hypothesis_heavy_ties(self, seed):
        # Small count alphabet -> dense ties, stressing tie-break order.
        rng = np.random.default_rng(seed)
        counts = rng.integers(0, 3, size=(8, 24)).astype(np.float32)
        assert_matches_ref(counts, k=8)


class TestDecay:
    def test_matches_ref(self):
        rng = np.random.default_rng(5)
        counts = rng.integers(0, 100, size=(64, 64)).astype(np.float32)
        out = pallas_decay(jnp.array(counts))
        np.testing.assert_array_equal(np.array(out), np.array(ref.decay(jnp.array(counts))))

    def test_integer_floor_semantics(self):
        counts = np.array([[0, 1, 2, 3, 4, 5, 6, 7]] * 8, np.float32)
        out = np.array(pallas_decay(jnp.array(counts)))
        np.testing.assert_array_equal(out[0], [0, 0, 1, 1, 2, 2, 3, 3])

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.sampled_from([8, 64, 128]),
        seed=st.integers(0, 2**32 - 1),
    )
    def test_hypothesis_sweep(self, n, seed):
        rng = np.random.default_rng(seed)
        counts = rng.integers(0, 2**20, size=(n, n)).astype(np.float32)
        out = pallas_decay(jnp.array(counts))
        np.testing.assert_array_equal(np.array(out), np.floor(counts * 0.5))

    def test_repeated_decay_reaches_zero(self):
        counts = jnp.full((8, 8), 100.0, jnp.float32)
        for _ in range(8):
            counts = pallas_decay(counts)
        assert np.all(np.array(counts) == 0.0)


class TestRefProperties:
    """Sanity of the oracle itself."""

    def test_normalize_handles_zero_rows(self):
        m = jnp.array([[0.0, 0.0], [1.0, 3.0]])
        p = np.array(ref.normalize_rows(m))
        np.testing.assert_allclose(p, [[0.0, 0.0], [0.25, 0.75]])

    def test_update_scatter_adds(self):
        c = jnp.zeros((4, 4), jnp.float32)
        c = ref.update(c, jnp.array([1, 1, 2]), jnp.array([0, 0, 3]))
        c = np.array(c)
        assert c[1, 0] == 2.0 and c[2, 3] == 1.0
        assert c.sum() == 3.0
