"""Layer-2 model tests + AOT artifact shape checks: the jitted entry points
compose correctly and every lowered artifact is valid HLO text with the
expected parameter/result shapes."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.kernels import ref
from compile.model import decay_fn, dense_infer, dense_update, infer_fn, update_fn


class TestDenseModel:
    def test_infer_gathers_correct_rows(self):
        n = 32
        rng = np.random.default_rng(0)
        counts = rng.integers(0, 9, size=(n, n)).astype(np.float32)
        queries = np.array([3, 7, 3, 0, 31, 1, 2, 2], np.int32)
        ids, probs, cum, totals = dense_infer(jnp.array(counts), jnp.array(queries), k=4)
        rid, rp, rc = ref.topk_cumprob(jnp.array(counts[queries]), 4)
        np.testing.assert_array_equal(np.array(ids), np.array(rid))
        np.testing.assert_allclose(np.array(probs), np.array(rp), atol=1e-6)
        np.testing.assert_allclose(np.array(cum), np.array(rc), atol=1e-6)
        np.testing.assert_allclose(np.array(totals), counts[queries].sum(axis=1))

    def test_update_then_infer_roundtrip(self):
        n = 16
        counts = jnp.zeros((n, n), jnp.float32)
        srcs = jnp.array([1] * 6 + [2] * 2, jnp.int32)
        dsts = jnp.array([5, 5, 5, 9, 9, 3, 0, 0], jnp.int32)
        counts = dense_update(counts, srcs, dsts)
        ids, probs, _, _ = dense_infer(counts, jnp.array([1] * 8, jnp.int32), k=3)
        assert np.array(ids)[0, 0] == 5  # 3/6
        np.testing.assert_allclose(np.array(probs)[0, 0], 0.5)
        assert np.array(ids)[0, 1] == 9  # 2/6

    def test_update_accumulates_duplicates(self):
        counts = jnp.zeros((8, 8), jnp.float32)
        counts = dense_update(
            counts, jnp.array([0, 0, 0], jnp.int32), jnp.array([1, 1, 1], jnp.int32)
        )
        assert np.array(counts)[0, 1] == 3.0

    def test_jit_entry_points_execute(self):
        for n, b, k in [(64, 8, 8)]:
            fn, args = infer_fn(n, b, k)
            jitted = jax.jit(fn)
            counts = jnp.ones((n, n), jnp.float32)
            queries = jnp.zeros((b,), jnp.int32)
            ids, probs, cum, totals = jitted(counts, queries)
            assert totals.shape == (b,)
            assert ids.shape == (b, k)
            assert probs.shape == (b, k)
            assert cum.shape == (b, k)

            ufn, _ = update_fn(n, b)
            new_counts = jax.jit(ufn)(counts, queries, queries)
            assert new_counts.shape == (n, n)

            dfn, _ = decay_fn(n)
            decayed = jax.jit(dfn)(counts)
            assert np.all(np.array(decayed) == 0.0)  # floor(0.5) == 0


@pytest.fixture(scope="module")
def artifacts_dir(tmp_path_factory):
    """Build artifacts into a temp dir (keeps the test hermetic); reuses the
    checked-in artifacts/ when already present to save time."""
    repo_artifacts = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if os.path.exists(os.path.join(repo_artifacts, "manifest.txt")):
        return repo_artifacts
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build(out)
    return out


class TestAotArtifacts:
    def test_manifest_lists_all_variants(self, artifacts_dir):
        with open(os.path.join(artifacts_dir, "manifest.txt")) as f:
            lines = [l.split() for l in f.read().splitlines() if l]
        kinds = {l[0] for l in lines}
        assert kinds == {"infer", "update", "decay"}
        assert len(lines) == 3 * len(aot.VARIANTS)
        for parts in lines:
            assert len(parts) == 5
            assert os.path.exists(os.path.join(artifacts_dir, parts[4])), parts[4]

    def test_hlo_text_is_parseable_hlo(self, artifacts_dir):
        with open(os.path.join(artifacts_dir, "manifest.txt")) as f:
            names = [l.split()[4] for l in f.read().splitlines() if l]
        for name in names:
            text = open(os.path.join(artifacts_dir, name)).read()
            assert text.startswith("HloModule"), f"{name} is not HLO text"
            assert "ENTRY" in text, name

    def test_infer_artifact_signature(self, artifacts_dir):
        n, b, k = aot.VARIANTS[0]
        name = f"dense_infer_n{n}_b{b}_k{k}.hlo.txt"
        text = open(os.path.join(artifacts_dir, name)).read()
        # Parameters: counts f32[n,n] and queries s32[b].
        assert f"f32[{n},{n}]" in text
        assert f"s32[{b}]" in text
        # Results include the [b, k] outputs.
        assert f"s32[{b},{k}]" in text
        assert f"f32[{b},{k}]" in text

    def test_no_custom_calls_in_artifacts(self, artifacts_dir):
        """interpret=True must lower to plain HLO ops — a Mosaic custom-call
        would make the artifact unloadable on the CPU PJRT plugin."""
        with open(os.path.join(artifacts_dir, "manifest.txt")) as f:
            names = [l.split()[4] for l in f.read().splitlines() if l]
        for name in names:
            text = open(os.path.join(artifacts_dir, name)).read()
            assert "custom-call" not in text, f"{name} contains a custom-call"
